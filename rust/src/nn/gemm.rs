//! Planned LUT-GEMM: code-sorted weight plans, per-row LUT-strip
//! expansion, runtime-dispatched SIMD accumulators, and a persistent
//! worker pool with shape-adaptive tiling.
//!
//! The flat-gather kernel ([`QuantLinear::gemm_batch_into`]) still pays a
//! 2D table index `(w << 4) | x` and a random 256-entry gather for every
//! single MAC. Weights are static, so that work can be compiled away:
//!
//! 1. **Plan compilation** (once, at backend construction). Each weight
//!    row's column indices are counting-sorted into 16 buckets, one per
//!    4-bit weight code — a 16-bucket CSR per output row
//!    ([`LayerPlan`]). The sort is stable, but order within a bucket is
//!    irrelevant anyway: the accumulator is exact integer arithmetic, so
//!    any summation order produces the same `i32` and therefore the same
//!    dequantized `f32` bit pattern as the per-sample path.
//!
//! 2. **LUT-strip expansion** (once per *input row*, not per MAC). The
//!    256-entry product table is expanded into a `16 × in_dim` strip
//!    `g[w][j] = table[(w << 4) | x_j]` of products (≤ 4 KiB for the
//!    digits model — L1-resident). Every MAC of every output row then
//!    reads this strip; the amortized per-MAC cost is one sequential
//!    `u16` column load plus one L1 strip load and an add — zero index
//!    arithmetic. Layers too narrow to amortize the 16-row expansion
//!    (`out_dim < 16`, e.g. a 10-class head) fall back to the flat
//!    gather per layer at compile time; the arithmetic is identical
//!    either way, only the instruction mix differs.
//!
//!    Bucket segments accumulate through one of four interchangeable
//!    kernels ([`StripKernel`]), chosen **once at plan-compile time** by
//!    [`GemmSimd::resolve`]: portable scalar, portable SWAR (4×16-bit
//!    lanes in one `u64`, see `swar_segment_sum`), AVX2 (8×`i32` lanes
//!    with hardware gather; x86_64 behind `is_x86_feature_detected!`)
//!    and NEON (widening pairwise accumulate; baseline on aarch64). The
//!    architecture-specific code — `std::arch` intrinsics and the
//!    `unsafe` that invokes them — is confined to the `simd` submodule
//!    (enforced by `repro lint`'s `simd-confined` rule). A segment sum
//!    is an exact integer sum, and integer addition is associative, so
//!    every kernel returns the identical `i32` — all four are
//!    bit-identical by construction, pinned against each other and the
//!    per-sample reference by `tests/gemm_plan.rs`.
//!
//! 3. **Persistent worker pool**. Multi-threaded plans hand work to a
//!    lazily-spawned pool of parked workers instead of paying the
//!    tens-of-µs `std::thread::scope` spawn per batch. The handoff is an
//!    owned-scratch state machine (`ChunkCell`) built on the
//!    [`crate::util::sync`] shim: the main thread moves a job (input
//!    pre-staged in the chunk's own scratch) into the cell, the parked
//!    worker wakes, runs it, and moves the scratch back. No borrows
//!    cross threads, no `unsafe`, and loom model-checks the protocol
//!    (`loom_models` below). Steady state allocates nothing: scratch
//!    buffers grow once during warmup and then shuttle by move.
//!
//! 4. **Shape-adaptive tiling** ([`MlpPlan::forward_batch_with`]).
//!    Throughput shapes (`batch ≥ threads`) partition across batch
//!    *rows*: each chunk runs the whole layer stack independently.
//!    Small-batch/wide shapes (`batch < threads`, the interactive case)
//!    partition each layer across *output-row spans* instead, so a
//!    batch-1 request finally scales with cores. Either way every
//!    output element is accumulated by exactly one thread in the same
//!    per-element order, so bit-exactness with [`QuantMlp::forward`]
//!    holds at every thread count, kernel and tiling mode
//!    ([`GemmPartition`], pinned by `tests/gemm_plan.rs`).

use super::{QuantLinear, QuantMlp, Quantizer};
use crate::multiplier::MultiplierModel;
use crate::util::sync::{Arc, Condvar, Mutex};

pub use simd::host_cpu_features;

/// Resolve a `gemm.threads` knob: `0` means one thread per available
/// core ([`std::thread::available_parallelism`]), anything else is taken
/// literally. Never returns 0.
pub fn resolve_threads(threads: usize) -> usize {
    if threads == 0 {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    } else {
        threads
    }
}

/// The `gemm.simd` knob: which strip accumulator a plan should compile
/// for. `Auto` (the default) picks the fastest kernel whose runtime
/// dispatch guard holds on this host; forcing an unavailable SIMD
/// kernel falls back to SWAR (the resolved choice is visible via
/// [`MlpPlan::kernel`]). Every choice is bit-identical — this knob
/// trades speed only, never accuracy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GemmSimd {
    /// Best available: AVX2, else NEON, else SWAR.
    Auto,
    /// Force the AVX2 kernel (x86_64 with AVX2; falls back to SWAR).
    Avx2,
    /// Force the NEON kernel (aarch64 only; falls back to SWAR).
    Neon,
    /// Force the portable SWAR kernel.
    Swar,
    /// Force the portable scalar kernel (the reference).
    Scalar,
}

impl GemmSimd {
    /// Every knob value (property tests sweep this).
    pub const ALL: [GemmSimd; 5] =
        [GemmSimd::Auto, GemmSimd::Avx2, GemmSimd::Neon, GemmSimd::Swar, GemmSimd::Scalar];

    /// Stable kebab-case identifier (config files, CLI).
    pub fn slug(self) -> &'static str {
        match self {
            GemmSimd::Auto => "auto",
            GemmSimd::Avx2 => "avx2",
            GemmSimd::Neon => "neon",
            GemmSimd::Swar => "swar",
            GemmSimd::Scalar => "scalar",
        }
    }

    /// Parse a slug (case-insensitive).
    pub fn parse_slug(s: &str) -> Option<GemmSimd> {
        match s.trim().to_ascii_lowercase().as_str() {
            "auto" => Some(GemmSimd::Auto),
            "avx2" => Some(GemmSimd::Avx2),
            "neon" => Some(GemmSimd::Neon),
            "swar" => Some(GemmSimd::Swar),
            "scalar" => Some(GemmSimd::Scalar),
            _ => None,
        }
    }

    /// Parse with the canonical error message.
    pub fn from_arg(s: &str) -> anyhow::Result<GemmSimd> {
        Self::parse_slug(s).ok_or_else(|| {
            anyhow::anyhow!("unknown gemm.simd `{s}` (known: auto, avx2, neon, swar, scalar)")
        })
    }

    /// Resolve the knob against this host's runtime dispatch guards.
    /// This is the **only** place a SIMD kernel can be selected, and it
    /// only returns one when the matching guard holds — the safety
    /// contract the `simd` module's wrappers rely on.
    pub fn resolve(self) -> StripKernel {
        match self {
            GemmSimd::Scalar => StripKernel::Scalar,
            GemmSimd::Swar => StripKernel::Swar,
            GemmSimd::Avx2 => {
                if simd::avx2_available() {
                    StripKernel::Avx2
                } else {
                    StripKernel::Swar
                }
            }
            GemmSimd::Neon => {
                if simd::neon_available() {
                    StripKernel::Neon
                } else {
                    StripKernel::Swar
                }
            }
            GemmSimd::Auto => {
                if simd::avx2_available() {
                    StripKernel::Avx2
                } else if simd::neon_available() {
                    StripKernel::Neon
                } else {
                    StripKernel::Swar
                }
            }
        }
    }
}

/// The `gemm.partition` knob: how a multi-threaded plan splits a batch
/// across its workers. All modes are bit-identical — each output
/// element is always accumulated by exactly one thread in the same
/// order — so, like [`GemmSimd`], this trades latency/throughput only.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GemmPartition {
    /// Rows when the batch can feed every thread (`batch ≥ threads`),
    /// output spans otherwise (the default).
    Auto,
    /// Always partition across batch rows (throughput shapes).
    Rows,
    /// Always partition each layer across output-row spans (interactive
    /// small-batch shapes — batch-1 latency scales with cores).
    Outputs,
}

impl GemmPartition {
    /// Every knob value (property tests sweep this).
    pub const ALL: [GemmPartition; 3] =
        [GemmPartition::Auto, GemmPartition::Rows, GemmPartition::Outputs];

    /// Stable kebab-case identifier (config files, CLI).
    pub fn slug(self) -> &'static str {
        match self {
            GemmPartition::Auto => "auto",
            GemmPartition::Rows => "rows",
            GemmPartition::Outputs => "outputs",
        }
    }

    /// Parse a slug (case-insensitive).
    pub fn parse_slug(s: &str) -> Option<GemmPartition> {
        match s.trim().to_ascii_lowercase().as_str() {
            "auto" => Some(GemmPartition::Auto),
            "rows" => Some(GemmPartition::Rows),
            "outputs" => Some(GemmPartition::Outputs),
            _ => None,
        }
    }

    /// Parse with the canonical error message.
    pub fn from_arg(s: &str) -> anyhow::Result<GemmPartition> {
        Self::parse_slug(s).ok_or_else(|| {
            anyhow::anyhow!("unknown gemm.partition `{s}` (known: auto, rows, outputs)")
        })
    }
}

/// Everything [`MlpPlan::compile_with`] needs from the `gemm.*` config
/// section: thread cap, kernel choice and tiling mode.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct GemmOptions {
    /// `gemm.threads` convention: `0` = one per available core.
    pub threads: usize,
    /// Strip-kernel choice, resolved at compile time.
    pub simd: GemmSimd,
    /// Batch tiling mode for multi-threaded plans.
    pub partition: GemmPartition,
}

impl Default for GemmOptions {
    fn default() -> Self {
        GemmOptions { threads: 1, simd: GemmSimd::Auto, partition: GemmPartition::Auto }
    }
}

impl GemmOptions {
    /// The historical single-knob constructor: given threads, keep the
    /// kernel and tiling on `auto`.
    pub fn with_threads(threads: usize) -> Self {
        GemmOptions { threads, ..Self::default() }
    }
}

/// A resolved strip accumulator — what [`GemmSimd::resolve`] turned the
/// knob into on this host. Plans carry this, never the raw knob, so a
/// plan's execution path is fixed (and reportable) at compile time.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StripKernel {
    /// Portable scalar reference.
    Scalar,
    /// Portable 4×16-bit SWAR lanes in a `u64`.
    Swar,
    /// 8×`i32` AVX2 lanes with hardware gather (x86_64).
    Avx2,
    /// Widening pairwise NEON accumulate (aarch64).
    Neon,
}

impl StripKernel {
    /// Stable identifier for bench JSON and the serve banner.
    pub fn slug(self) -> &'static str {
        match self {
            StripKernel::Scalar => "scalar",
            StripKernel::Swar => "swar",
            StripKernel::Avx2 => "avx2",
            StripKernel::Neon => "neon",
        }
    }
}

/// Runtime-dispatched SIMD strip accumulators.
///
/// Every architecture-specific token in the crate — `std::arch`
/// intrinsics and the `unsafe` blocks that invoke them — lives inside
/// this module and nowhere else; `repro lint`'s `simd-confined` rule
/// enforces the boundary, and requires each `unsafe` block's SAFETY
/// comment to name the runtime-dispatch guard it relies on. The public
/// functions are safe wrappers: plans can only select a SIMD kernel
/// through `GemmSimd::resolve`, which checks the matching guard
/// (`is_x86_feature_detected!("avx2")` on x86_64, the baseline-NEON
/// compile target on aarch64) before handing the kernel out.
mod simd {
    /// Whether the AVX2 kernel's runtime dispatch guard holds.
    pub fn avx2_available() -> bool {
        #[cfg(target_arch = "x86_64")]
        {
            std::arch::is_x86_feature_detected!("avx2")
        }
        #[cfg(not(target_arch = "x86_64"))]
        {
            false
        }
    }

    /// Whether the NEON kernel may run. NEON is baseline on aarch64, so
    /// the guard is a compile-target fact, not a CPUID probe.
    pub fn neon_available() -> bool {
        cfg!(target_arch = "aarch64")
    }

    /// Host arch plus the SIMD features the dispatcher detected, e.g.
    /// `x86_64+avx2` — recorded in `BENCH_lut_gemm.json` so a perf data
    /// point names the hardware path it measured.
    pub fn host_cpu_features() -> String {
        let mut s = String::from(std::env::consts::ARCH);
        if avx2_available() {
            s.push_str("+avx2");
        }
        if neon_available() {
            s.push_str("+neon");
        }
        s
    }

    /// AVX2 bucket-segment sum over the widened `i32` strip: eight
    /// `u16` column indices load as one vector, widen to `i32×8`, one
    /// hardware gather fetches eight strip products, and eight `i32`
    /// lanes accumulate. Products are `u8`-range (≤ 255) and a segment
    /// holds at most `in_dim ≤ 65 536` columns, so a lane sum stays
    /// below `65 536 · 255 < 2³¹` — no overflow — and integer addition
    /// is associative, so the horizontal fold equals the scalar sum
    /// bit-for-bit. The `seg.len() % 8` tail is summed scalar.
    #[cfg(target_arch = "x86_64")]
    pub fn avx2_segment_sum(seg: &[u16], srow: &[i32]) -> i32 {
        debug_assert!(seg.iter().all(|&c| (c as usize) < srow.len()));
        // SAFETY: calling the AVX2-featured function is sound because
        // the runtime dispatch guard holds — `GemmSimd::resolve` only
        // selects `StripKernel::Avx2` after `avx2_available()`
        // (`is_x86_feature_detected!("avx2")`) returned true on this
        // host, and plans never call this wrapper with any other kernel
        // resolved.
        unsafe { avx2_segment_sum_impl(seg, srow) }
    }

    /// The AVX2 body. Safe to call only where the `avx2` target feature
    /// is known enabled (see the dispatch guard in `avx2_segment_sum`).
    #[cfg(target_arch = "x86_64")]
    #[target_feature(enable = "avx2")]
    fn avx2_segment_sum_impl(seg: &[u16], srow: &[i32]) -> i32 {
        use std::arch::x86_64::*;
        let base = srow.as_ptr();
        let mut acc = _mm256_setzero_si256();
        let mut chunks = seg.chunks_exact(8);
        for c in chunks.by_ref() {
            // SAFETY: the runtime dispatch guard (see `avx2_segment_sum`)
            // guarantees AVX2; the unaligned load reads exactly the
            // eight `u16` indices of `c`, and each gathered lane reads
            // `srow[c[i]]` with `c[i] < in_dim ≤ srow.len()` — column
            // indices are bounds-asserted at plan compile (and
            // debug-asserted in the wrapper).
            unsafe {
                let idx16 = _mm_loadu_si128(c.as_ptr() as *const __m128i);
                let idx32 = _mm256_cvtepu16_epi32(idx16);
                acc = _mm256_add_epi32(acc, _mm256_i32gather_epi32::<4>(base, idx32));
            }
        }
        // horizontal fold: 8 lanes -> 4 -> 2 -> 1 (pure lane shuffles
        // and adds — exact integer sums in any order)
        let quad = _mm_add_epi32(_mm256_castsi256_si128(acc), _mm256_extracti128_si256::<1>(acc));
        let pair = _mm_add_epi32(quad, _mm_shuffle_epi32::<0x0E>(quad));
        let mut sum = _mm_cvtsi128_si32(_mm_add_epi32(pair, _mm_shuffle_epi32::<0x01>(pair)));
        for &c in chunks.remainder() {
            sum += srow[c as usize];
        }
        sum
    }

    /// NEON bucket-segment sum: NEON has no gather, so eight strip
    /// products are staged into a stack buffer scalar-wise, then one
    /// widening pairwise-accumulate (`vpadalq_s16`) folds them into
    /// four `i32` lanes. Per chunk a lane gains two ≤ 255 products, so
    /// a lane sum stays below `(65 536 / 8) · 2 · 255 < 2³¹`; the
    /// horizontal `vaddvq_s32` fold and the scalar tail make the result
    /// equal the scalar sum bit-for-bit (exact integer arithmetic).
    #[cfg(target_arch = "aarch64")]
    pub fn neon_segment_sum(seg: &[u16], srow: &[i16]) -> i32 {
        use std::arch::aarch64::{vaddvq_s32, vdupq_n_s32, vld1q_s16, vpadalq_s16};
        debug_assert!(seg.iter().all(|&c| (c as usize) < srow.len()));
        let mut acc = vdupq_n_s32(0);
        let mut buf = [0i16; 8];
        let mut chunks = seg.chunks_exact(8);
        for c in chunks.by_ref() {
            for (d, &ci) in buf.iter_mut().zip(c) {
                *d = srow[ci as usize];
            }
            // SAFETY: the dispatch guard for NEON is the
            // `target_arch = "aarch64"` gate on this function itself
            // (NEON is architecturally baseline there, which is exactly
            // what `neon_available` reports to `GemmSimd::resolve`);
            // the load reads the eight `i16`s of the stack buffer
            // filled just above.
            let v = unsafe { vld1q_s16(buf.as_ptr()) };
            acc = vpadalq_s16(acc, v);
        }
        let mut sum = vaddvq_s32(acc);
        for &c in chunks.remainder() {
            sum += srow[c as usize] as i32;
        }
        sum
    }
}

/// One [`QuantLinear`] compiled for planned execution: per output row,
/// the column indices grouped by 4-bit weight code (a 16-bucket CSR).
/// Weight codes are static, so this is built once per backend and shared
/// read-only across worker GEMM threads.
#[derive(Debug, Clone)]
pub struct LayerPlan {
    in_dim: usize,
    out_dim: usize,
    /// `out_dim × in_dim` column indices; row `r` occupies
    /// `cols[r·in_dim .. (r+1)·in_dim]`, grouped by weight code.
    cols: Vec<u16>,
    /// `out_dim × 17` absolute offsets into `cols`: row `r`'s bucket for
    /// code `w` is `cols[offs[r·17 + w] .. offs[r·17 + w + 1]]`.
    offs: Vec<u32>,
    /// Row-major weight codes — populated only for flat-gather fallback
    /// layers (empty when the strip path runs, which never reads codes).
    wq: Vec<u8>,
    /// Whether the strip path pays for itself (see [`LayerPlan::compile`]):
    /// expanding 16 strip rows only amortizes over enough output rows.
    use_strip: bool,
    w_quant: Quantizer,
    x_quant: Quantizer,
    bias: Vec<f32>,
    relu: bool,
}

impl LayerPlan {
    /// Compile a layer's static weight codes into the bucketed plan.
    pub fn compile(layer: &QuantLinear) -> Self {
        let (in_dim, out_dim) = (layer.in_dim, layer.out_dim);
        assert!(in_dim <= u16::MAX as usize + 1, "in_dim {in_dim} exceeds u16 column indices");
        assert!(
            in_dim.checked_mul(out_dim).is_some_and(|n| n <= u32::MAX as usize),
            "{out_dim}x{in_dim} weight elements exceed u32 plan offsets"
        );
        assert!(
            layer.wq.iter().all(|&w| w < 16),
            "weight codes must be 4-bit to compile a LayerPlan"
        );
        let use_strip = out_dim >= 16;
        let mut cols = vec![0u16; in_dim * out_dim];
        let mut offs = Vec::with_capacity(out_dim * 17);
        for r in 0..out_dim {
            let row = &layer.wq[r * in_dim..(r + 1) * in_dim];
            let base = (r * in_dim) as u32;
            // counting sort of the row's columns by weight code
            let mut counts = [0u32; 16];
            for &w in row {
                counts[w as usize] += 1;
            }
            let mut cursor = [0u32; 16];
            let mut acc = 0u32;
            for w in 0..16 {
                offs.push(base + acc);
                cursor[w] = base + acc;
                acc += counts[w];
            }
            offs.push(base + acc);
            for (j, &w) in row.iter().enumerate() {
                cols[cursor[w as usize] as usize] = j as u16;
                cursor[w as usize] += 1;
            }
        }
        LayerPlan {
            in_dim,
            out_dim,
            cols,
            offs,
            // The strip path never reads the raw codes; keep the copy
            // only for the flat-gather fallback of narrow heads.
            wq: if use_strip { Vec::new() } else { layer.wq.clone() },
            // The strip costs 16·in_dim expansion entries per input row
            // and saves per-MAC index arithmetic on out_dim·in_dim MACs;
            // with fewer output rows than strip rows the expansion can't
            // amortize, so narrow heads fall back to the flat gather
            // (numerically identical — only the instruction mix differs).
            use_strip,
            w_quant: layer.w_quant,
            x_quant: layer.x_quant,
            bias: layer.bias.clone(),
            relu: layer.relu,
        }
    }

    /// Whether this layer executes via the LUT strip (wide layers) or
    /// the flat-gather fallback (narrow heads). Both are bit-exact.
    pub fn uses_strip(&self) -> bool {
        self.use_strip
    }

    /// Approximate heap footprint of the compiled buffers — what keeping
    /// this layer's plan resident actually costs a cache.
    pub fn heap_bytes(&self) -> usize {
        self.cols.len() * std::mem::size_of::<u16>()
            + self.offs.len() * std::mem::size_of::<u32>()
            + self.wq.len()
            + self.bias.len() * std::mem::size_of::<f32>()
    }

    pub fn in_dim(&self) -> usize {
        self.in_dim
    }

    pub fn out_dim(&self) -> usize {
        self.out_dim
    }

    /// Planned GEMM over `rows` pre-quantized input rows with the SWAR
    /// kernel: expands the LUT strip once per input row, then sums each
    /// output row's buckets with sequential column reads. Writes
    /// `rows × out_dim` dequantized (bias + ReLU applied) activations
    /// into `out`, clearing it first. Bit-exact with
    /// [`QuantLinear::gemm_batch_into`].
    pub fn gemm_rows_into(
        &self,
        xq: &[u8],
        rows: usize,
        model: &MultiplierModel,
        scratch: &mut StripScratch,
        out: &mut Vec<f32>,
    ) {
        self.gemm_rows_span(xq, rows, model, scratch, out, StripKernel::Swar, 0..self.out_dim);
    }

    /// The reference kernel: identical to [`LayerPlan::gemm_rows_into`]
    /// but with the scalar strip accumulator — the baseline every other
    /// kernel is pinned against (`benches/lut_gemm.rs` races them to
    /// quantify the win per layer; `tests/gemm_plan.rs` asserts
    /// bit-identity).
    pub fn gemm_rows_into_scalar(
        &self,
        xq: &[u8],
        rows: usize,
        model: &MultiplierModel,
        scratch: &mut StripScratch,
        out: &mut Vec<f32>,
    ) {
        self.gemm_rows_span(xq, rows, model, scratch, out, StripKernel::Scalar, 0..self.out_dim);
    }

    /// [`LayerPlan::gemm_rows_into`] with an explicit resolved kernel —
    /// what plans and the kernel-race bench call. The caller owns the
    /// dispatch contract: a SIMD kernel must come from
    /// [`GemmSimd::resolve`] on this host.
    pub fn gemm_rows_into_kernel(
        &self,
        xq: &[u8],
        rows: usize,
        model: &MultiplierModel,
        scratch: &mut StripScratch,
        out: &mut Vec<f32>,
        kernel: StripKernel,
    ) {
        self.gemm_rows_span(xq, rows, model, scratch, out, kernel, 0..self.out_dim);
    }

    /// The planned-GEMM core: run `rows` input rows through the output
    /// rows `span` only, writing a dense `rows × span.len()` block into
    /// `out` (cleared first). Output-span tiling calls this with
    /// disjoint spans from different threads; every output element is
    /// produced by exactly one call in the same per-element operation
    /// order, so stitching spans is bit-identical to one full-span call.
    pub fn gemm_rows_span(
        &self,
        xq: &[u8],
        rows: usize,
        model: &MultiplierModel,
        scratch: &mut StripScratch,
        out: &mut Vec<f32>,
        kernel: StripKernel,
        span: std::ops::Range<usize>,
    ) {
        assert_eq!(xq.len(), rows * self.in_dim, "bad batch input shape");
        assert!(span.start <= span.end && span.end <= self.out_dim, "bad output span");
        let table = model.table();
        let zp = self.w_quant.zero_point as i32;
        out.clear();
        out.reserve(rows * (span.end - span.start));
        for b in 0..rows {
            let xrow = &xq[b * self.in_dim..(b + 1) * self.in_dim];
            let corr = zp * xrow.iter().map(|&x| x as i32).sum::<i32>();
            if self.use_strip {
                scratch.expand(table, xrow, kernel);
            }
            for r in span.clone() {
                let acc = if self.use_strip {
                    self.accumulate_strip(r, scratch, kernel)
                } else {
                    self.accumulate_flat(r, xrow, table)
                };
                // identical operation order to the flat-gather path —
                // float multiplication is not associative, so the scales
                // must not be pre-folded
                let v = (acc - corr) as f32 * self.w_quant.scale * self.x_quant.scale
                    + self.bias[r];
                out.push(if self.relu { v.max(0.0) } else { v });
            }
        }
    }

    /// Strip inner loop: sequential column reads over pre-gathered
    /// products, each bucket segment summed by the resolved kernel.
    #[inline]
    fn accumulate_strip(&self, r: usize, scratch: &StripScratch, kernel: StripKernel) -> i32 {
        let ro = &self.offs[r * 17..r * 17 + 17];
        let mut acc = 0i32;
        for w in 0..16 {
            let seg = &self.cols[ro[w] as usize..ro[w + 1] as usize];
            if seg.is_empty() {
                continue;
            }
            acc += match kernel {
                StripKernel::Scalar => scalar_segment_sum(seg, self.srow16(scratch, w)),
                StripKernel::Swar => swar_segment_sum(seg, self.srow16(scratch, w)),
                StripKernel::Avx2 => self.avx2_segment(seg, w, scratch),
                StripKernel::Neon => self.neon_segment(seg, w, scratch),
            };
        }
        acc
    }

    /// Code `w`'s row of the `i16` strip.
    #[inline]
    fn srow16<'a>(&self, scratch: &'a StripScratch, w: usize) -> &'a [i16] {
        &scratch.strip[w * self.in_dim..(w + 1) * self.in_dim]
    }

    /// AVX2 segment sum over the widened strip; structurally unreachable
    /// off x86_64 ([`GemmSimd::resolve`] never hands the kernel out
    /// there).
    #[inline]
    fn avx2_segment(&self, seg: &[u16], w: usize, scratch: &StripScratch) -> i32 {
        #[cfg(target_arch = "x86_64")]
        {
            simd::avx2_segment_sum(seg, &scratch.strip32[w * self.in_dim..(w + 1) * self.in_dim])
        }
        #[cfg(not(target_arch = "x86_64"))]
        {
            let _ = (seg, w, scratch);
            unreachable!("AVX2 kernel resolved off x86_64")
        }
    }

    /// NEON segment sum; structurally unreachable off aarch64.
    #[inline]
    fn neon_segment(&self, seg: &[u16], w: usize, scratch: &StripScratch) -> i32 {
        #[cfg(target_arch = "aarch64")]
        {
            simd::neon_segment_sum(seg, self.srow16(scratch, w))
        }
        #[cfg(not(target_arch = "aarch64"))]
        {
            let _ = (seg, w, scratch);
            unreachable!("NEON kernel resolved off aarch64")
        }
    }

    /// Flat-gather inner loop (same arithmetic as
    /// [`QuantLinear::gemm_batch_into`]) for layers too narrow to
    /// amortize the strip expansion.
    #[inline]
    fn accumulate_flat(&self, r: usize, xrow: &[u8], table: &[u8; 256]) -> i32 {
        let wrow = &self.wq[r * self.in_dim..(r + 1) * self.in_dim];
        wrow.iter()
            .zip(xrow)
            .map(|(&w, &x)| table[((w as usize) << 4) | x as usize] as i32)
            .sum()
    }
}

/// How many packed adds the SWAR accumulator performs before flushing
/// its lanes into the wide sum. Strip products come from a
/// [`MultiplierModel`] table of `u8`s — an *exact* multiplier caps them
/// at 15·15 = 225, but approximate tables may hold any `u8`, so the
/// guaranteed bound is the `u8` maximum 255. After 256 packed adds a
/// 16-bit lane holds at most 256 · 255 = 65 280 < 2¹⁶, so no lane can
/// ever carry into its neighbour. Do NOT raise this above 256: the
/// safety margin is sized for 255-valued products, not 225. (With
/// `in_dim ≤ 4096` a bucket segment packs at most 1024 adds — at most
/// four flushes per segment.)
const SWAR_FLUSH_EVERY: u32 = 256;

/// Sum `srow[c]` over a bucket segment's column indices, four columns
/// per step: the gathered `i16` products (non-negative, ≤ 255 — see
/// [`SWAR_FLUSH_EVERY`]) pack into one `u64` as 4×16-bit lanes, so four
/// scalar adds collapse into a single 64-bit add. Lanes flush into a
/// plain sum before they can overflow and the `seg.len() % 4` tail is
/// summed scalar, so the result equals the scalar sum exactly — integer
/// addition is associative, making the kernel bit-identical to
/// [`scalar_segment_sum`] by construction.
#[inline]
fn swar_segment_sum(seg: &[u16], srow: &[i16]) -> i32 {
    let mut total = 0u64;
    let mut packed = 0u64;
    let mut packs = 0u32;
    let mut chunks = seg.chunks_exact(4);
    for c in chunks.by_ref() {
        let p = (srow[c[0] as usize] as u16 as u64)
            | ((srow[c[1] as usize] as u16 as u64) << 16)
            | ((srow[c[2] as usize] as u16 as u64) << 32)
            | ((srow[c[3] as usize] as u16 as u64) << 48);
        packed += p;
        packs += 1;
        if packs == SWAR_FLUSH_EVERY {
            total += flush_lanes(packed);
            packed = 0;
            packs = 0;
        }
    }
    total += flush_lanes(packed);
    let mut sum = total as i32;
    for &c in chunks.remainder() {
        sum += srow[c as usize] as i32;
    }
    sum
}

/// Sum the four 16-bit lanes of a SWAR accumulator.
#[inline]
fn flush_lanes(packed: u64) -> u64 {
    (packed & 0xffff) + ((packed >> 16) & 0xffff) + ((packed >> 32) & 0xffff) + (packed >> 48)
}

/// The scalar strip accumulator (the SWAR/SIMD tail and reference path).
#[inline]
fn scalar_segment_sum(seg: &[u16], srow: &[i16]) -> i32 {
    let mut sum = 0i32;
    for &c in seg {
        sum += srow[c as usize] as i32;
    }
    sum
}

/// Expand the 256-entry product table into the per-code lookup strip for
/// one input row: `strip[w·in_dim + j] = table[(w << 4) | x_j]`. Table
/// entries are `u8` (≤ 255; exact multipliers cap at 15·15 = 225), so
/// `i16` holds them losslessly.
fn expand_strip(table: &[u8; 256], xrow: &[u8], strip: &mut Vec<i16>) {
    strip.clear();
    strip.reserve(16 * xrow.len());
    for w in 0..16usize {
        let base = w << 4;
        let trow = &table[base..base + 16];
        strip.extend(xrow.iter().map(|&x| trow[(x & 0xf) as usize] as i16));
    }
}

/// [`expand_strip`] widened to `i32` for the AVX2 kernel, whose hardware
/// gather reads exactly one 4-byte element per lane. Same values, wider
/// cells — the segment sums are identical integers either way.
fn expand_strip32(table: &[u8; 256], xrow: &[u8], strip: &mut Vec<i32>) {
    strip.clear();
    strip.reserve(16 * xrow.len());
    for w in 0..16usize {
        let base = w << 4;
        let trow = &table[base..base + 16];
        strip.extend(xrow.iter().map(|&x| trow[(x & 0xf) as usize] as i32));
    }
}

/// Reusable LUT-strip buffers for one GEMM thread. The `i16` strip
/// feeds the scalar/SWAR/NEON kernels; the `i32` strip is expanded only
/// when the AVX2 kernel runs (its gather wants 4-byte elements). Grows
/// once, then reused for every input row.
#[derive(Debug, Default)]
pub struct StripScratch {
    strip: Vec<i16>,
    strip32: Vec<i32>,
}

impl StripScratch {
    /// Expand the strip the given kernel reads for one input row.
    fn expand(&mut self, table: &[u8; 256], xrow: &[u8], kernel: StripKernel) {
        match kernel {
            StripKernel::Avx2 => expand_strip32(table, xrow, &mut self.strip32),
            _ => expand_strip(table, xrow, &mut self.strip),
        }
    }
}

/// Per-chunk scratch: quantized codes, ping-pong activation buffers and
/// the LUT strips. Owned by exactly one thread at a time — the pool
/// handoff moves it into a job and back — and reused across batches.
#[derive(Debug, Default)]
struct ChunkScratch {
    xq: Vec<u8>,
    cur: Vec<f32>,
    next: Vec<f32>,
    strips: StripScratch,
}

/// What the main thread hands a pool worker: the shared layer stack, the
/// resolved kernel, the multiplier table (Copy), and the chunk's own
/// scratch with the input pre-staged. Everything is owned or
/// refcounted, so the handoff needs no lifetimes and no `unsafe`.
#[derive(Debug)]
struct ChunkJob {
    layers: std::sync::Arc<Vec<LayerPlan>>,
    kernel: StripKernel,
    model: MultiplierModel,
    rows: usize,
    task: JobTask,
    scratch: ChunkScratch,
}

/// The two tiling shapes a job can carry (see [`GemmPartition`]).
#[derive(Debug)]
enum JobTask {
    /// Run the whole layer stack over this chunk's batch rows: input in
    /// `scratch.cur`, logits left in `scratch.cur`.
    Stack,
    /// Run one layer's output span `r0..r1`: quantized input in
    /// `scratch.xq`, the dense `rows × (r1-r0)` block left in
    /// `scratch.next`.
    Span { layer: usize, r0: usize, r1: usize },
}

/// The handoff state machine between the main thread and one parked
/// pool worker. A cell is always in exactly one state, and scratch
/// ownership follows the state: `Ready`/`Done` hold it, `Idle`/
/// `Running` mean the other side does. Protocol (loom-modeled in
/// `loom_models`):
///
/// ```text
/// main: submit(job)  Idle -> Ready      worker: next_job()  Ready -> Running
/// worker: complete() Running -> Done    main: await_done()  Done -> Idle
/// drop: stop()       * -> Stopped       worker: next_job() -> None, exits
/// ```
///
/// A worker that panics never reaches `complete`, so `await_done` would
/// block; jobs contain no user input and every kernel is panic-free on
/// plan-validated shapes (the same contract `std::thread::scope`
/// relied on).
struct ChunkCell {
    state: Mutex<CellState>,
    cv: Condvar,
}

enum CellState {
    Idle,
    Ready(ChunkJob),
    Running,
    Done(ChunkScratch),
    Stopped,
}

impl ChunkCell {
    fn new() -> Self {
        ChunkCell { state: Mutex::new(CellState::Idle), cv: Condvar::new() }
    }

    /// Main side: hand a job to the worker. The cell must be idle — the
    /// plan always reclaims a worker's previous job before resubmitting.
    fn submit(&self, job: ChunkJob) {
        let mut st = self.state.lock().unwrap();
        debug_assert!(matches!(*st, CellState::Idle), "submit to a non-idle pool worker");
        *st = CellState::Ready(job);
        self.cv.notify_all();
    }

    /// Worker side: park until a job arrives; `None` means stop.
    fn next_job(&self) -> Option<ChunkJob> {
        let mut st = self.state.lock().unwrap();
        loop {
            match std::mem::replace(&mut *st, CellState::Running) {
                CellState::Ready(job) => return Some(job),
                CellState::Stopped => {
                    *st = CellState::Stopped;
                    return None;
                }
                other => {
                    *st = other;
                    st = self.cv.wait(st).unwrap();
                }
            }
        }
    }

    /// Worker side: publish the finished job's scratch back to the main
    /// thread.
    fn complete(&self, scratch: ChunkScratch) {
        let mut st = self.state.lock().unwrap();
        *st = CellState::Done(scratch);
        self.cv.notify_all();
    }

    /// Main side: block until the worker publishes, reclaim the scratch.
    fn await_done(&self) -> ChunkScratch {
        let mut st = self.state.lock().unwrap();
        loop {
            match std::mem::replace(&mut *st, CellState::Idle) {
                CellState::Done(scratch) => return scratch,
                other => {
                    *st = other;
                    st = self.cv.wait(st).unwrap();
                }
            }
        }
    }

    /// Ask the worker to exit (wakes it if parked).
    fn stop(&self) {
        let mut st = self.state.lock().unwrap();
        *st = CellState::Stopped;
        self.cv.notify_all();
    }
}

/// A pool worker's park-run loop: take a job, run it, hand the scratch
/// back, park again. Shared between the spawned threads and the loom
/// model.
fn worker_loop(cell: &ChunkCell) {
    while let Some(mut job) = cell.next_job() {
        run_job(&mut job);
        cell.complete(job.scratch);
    }
}

/// Execute one pool job in its own scratch.
fn run_job(job: &mut ChunkJob) {
    match job.task {
        JobTask::Stack => {
            run_chunk_in_place(&job.layers, job.kernel, job.rows, &job.model, &mut job.scratch);
        }
        JobTask::Span { layer, r0, r1 } => {
            let ChunkScratch { xq, next, strips, .. } = &mut job.scratch;
            let (kernel, rows) = (job.kernel, job.rows);
            job.layers[layer].gemm_rows_span(xq, rows, &job.model, strips, next, kernel, r0..r1);
        }
    }
}

/// Run `rows` batch rows (staged in `slot.cur`) through every layer,
/// leaving the logits in `slot.cur`.
fn run_chunk_in_place(
    layers: &[LayerPlan],
    kernel: StripKernel,
    rows: usize,
    model: &MultiplierModel,
    slot: &mut ChunkScratch,
) {
    let ChunkScratch { xq, cur, next, strips } = slot;
    for layer in layers {
        xq.clear();
        xq.extend(cur.iter().map(|&x| layer.x_quant.quantize(x)));
        layer.gemm_rows_span(xq, rows, model, strips, next, kernel, 0..layer.out_dim);
        std::mem::swap(cur, next);
    }
}

/// One parked pool thread and the scratch the main thread stages its
/// jobs in (`None` while a job is in flight).
struct PoolWorker {
    cell: Arc<ChunkCell>,
    scratch: Option<ChunkScratch>,
    #[cfg(not(loom))]
    handle: Option<std::thread::JoinHandle<()>>,
}

/// The lazily-grown persistent worker pool. Threads spawn on the first
/// batch that fans out (warmup) and then park on their cells between
/// batches — the steady-state handoff is two mutex/condvar exchanges
/// per worker and zero allocations. Dropping the pool stops and joins
/// every worker.
#[derive(Default)]
struct WorkerPool {
    workers: Vec<PoolWorker>,
}

impl std::fmt::Debug for WorkerPool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("WorkerPool").field("workers", &self.workers.len()).finish()
    }
}

impl WorkerPool {
    /// Grow the pool to at least `n` parked workers (no-op once warm).
    #[cfg(not(loom))]
    fn ensure(&mut self, n: usize) {
        while self.workers.len() < n {
            let cell = Arc::new(ChunkCell::new());
            let worker_cell = Arc::clone(&cell);
            let handle = std::thread::Builder::new()
                .name(format!("luna-gemm-{}", self.workers.len()))
                .spawn(move || worker_loop(&worker_cell))
                .expect("spawn GEMM pool worker");
            self.workers.push(PoolWorker {
                cell,
                scratch: Some(ChunkScratch::default()),
                handle: Some(handle),
            });
        }
    }

    /// Under loom, plan execution is forced single-threaded (the
    /// handoff protocol is modeled directly in `loom_models`), so the
    /// pool never grows.
    #[cfg(loom)]
    fn ensure(&mut self, _n: usize) {
        unreachable!("the GEMM pool never grows under loom");
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        for w in &self.workers {
            w.cell.stop();
        }
        #[cfg(not(loom))]
        for w in &mut self.workers {
            if let Some(handle) = w.handle.take() {
                let _ = handle.join();
            }
        }
    }
}

/// Reusable execution state for [`MlpPlan::forward_batch_with`]: the
/// main thread's chunk scratch, a dense span staging buffer (output
/// tiling), and the persistent worker pool. Everything grows during
/// warmup and is reused — steady-state planned inference allocates
/// nothing but the returned logits.
#[derive(Debug, Default)]
pub struct PlanScratch {
    main: ChunkScratch,
    span_out: Vec<f32>,
    pool: WorkerPool,
}

/// A [`QuantMlp`] compiled for planned execution: one [`LayerPlan`] per
/// layer (refcounted so pool jobs can share it without lifetimes), the
/// resolved GEMM thread cap, the resolved strip kernel and the tiling
/// mode.
#[derive(Debug, Clone)]
pub struct MlpPlan {
    layers: std::sync::Arc<Vec<LayerPlan>>,
    threads: usize,
    kernel: StripKernel,
    partition: GemmPartition,
}

impl MlpPlan {
    /// Compile every layer with the default kernel/tiling knobs
    /// (`auto`). `threads` follows the `gemm.threads` convention (`0` =
    /// one per available core); the resolved count is an upper bound —
    /// a batch never fans out wider than its work supports.
    pub fn compile(mlp: &QuantMlp, threads: usize) -> Self {
        Self::compile_with(mlp, GemmOptions::with_threads(threads))
    }

    /// Compile every layer, resolving the full `gemm.*` knob set: the
    /// thread cap, the strip kernel (runtime dispatch happens **here**,
    /// once) and the tiling mode.
    pub fn compile_with(mlp: &QuantMlp, opts: GemmOptions) -> Self {
        MlpPlan {
            layers: std::sync::Arc::new(mlp.layers.iter().map(QuantLinear::plan).collect()),
            threads: resolve_threads(opts.threads),
            kernel: opts.simd.resolve(),
            partition: opts.partition,
        }
    }

    /// Resolved GEMM thread cap (≥ 1).
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// The strip kernel this plan dispatched to at compile time.
    pub fn kernel(&self) -> StripKernel {
        self.kernel
    }

    /// The tiling mode this plan was compiled with (`Auto` resolves per
    /// batch: rows when `batch ≥ threads`, output spans otherwise).
    pub fn partition(&self) -> GemmPartition {
        self.partition
    }

    /// Approximate heap footprint of the compiled plan (all layers) —
    /// the unit of account for the serving plan cache's byte budget.
    pub fn heap_bytes(&self) -> usize {
        self.layers.iter().map(LayerPlan::heap_bytes).sum()
    }

    pub fn input_dim(&self) -> usize {
        self.layers[0].in_dim
    }

    pub fn output_dim(&self) -> usize {
        self.layers.last().unwrap().out_dim
    }

    /// Planned batched forward pass with fresh scratch (tests, one-off
    /// callers). See [`MlpPlan::forward_batch_with`].
    pub fn forward_batch(&self, xs: &[f32], batch: usize, model: &MultiplierModel) -> Vec<f32> {
        let mut scratch = PlanScratch::default();
        self.forward_batch_with(xs, batch, model, &mut scratch)
    }

    /// Planned batched forward pass: `xs` is row-major
    /// `batch × input_dim`, returns row-major `batch × output_dim`
    /// logits. Work fans out across up to [`MlpPlan::threads`] threads
    /// under the compiled [`GemmPartition`]; every output element is
    /// accumulated by exactly one thread in the same order, so results
    /// are bit-exact with [`QuantMlp::forward`] per row regardless of
    /// thread count, kernel or tiling mode.
    ///
    /// Worker threads come from the persistent pool inside `scratch`:
    /// they spawn once, on the first batch that fans out, and park
    /// between batches — the per-batch cost is a condvar wake per
    /// worker, not a thread spawn. The serving default (`gemm.threads
    /// 1`, see [`crate::config::GemmConfig`]) never wakes the pool.
    pub fn forward_batch_with(
        &self,
        xs: &[f32],
        batch: usize,
        model: &MultiplierModel,
        scratch: &mut PlanScratch,
    ) -> Vec<f32> {
        let mut out = Vec::new();
        self.forward_batch_into(xs, batch, model, scratch, &mut out);
        out
    }

    /// [`MlpPlan::forward_batch_with`] writing the logits into a
    /// caller-owned buffer (cleared first), so a long-lived backend that
    /// draws `out` from the buffer pool serves batches with zero heap
    /// allocations (see [`crate::util::pool`]).
    pub fn forward_batch_into(
        &self,
        xs: &[f32],
        batch: usize,
        model: &MultiplierModel,
        scratch: &mut PlanScratch,
        out: &mut Vec<f32>,
    ) {
        let in_dim = self.input_dim();
        let out_dim = self.output_dim();
        assert_eq!(xs.len(), batch * in_dim, "bad batch input shape");
        out.clear();
        out.resize(batch * out_dim, 0.0);
        if batch == 0 {
            return;
        }
        // Loom builds never fan out: the pool handoff protocol is
        // modeled directly (see `loom_models`), and loom threads cannot
        // outlive a model iteration the way pool workers outlive a call.
        let threads = if cfg!(loom) { 1 } else { self.threads };
        if threads == 1 {
            let main = &mut scratch.main;
            main.cur.clear();
            main.cur.extend_from_slice(xs);
            run_chunk_in_place(&self.layers, self.kernel, batch, model, main);
            out.copy_from_slice(&main.cur);
            return;
        }
        let partition = match self.partition {
            GemmPartition::Auto if batch >= threads => GemmPartition::Rows,
            GemmPartition::Auto => GemmPartition::Outputs,
            forced => forced,
        };
        match partition {
            GemmPartition::Rows => self.forward_rows(xs, batch, model, scratch, out, threads),
            _ => self.forward_outputs(xs, batch, model, scratch, out, threads),
        }
    }

    /// Row tiling: contiguous batch-row chunks, one per thread; each
    /// chunk runs the whole layer stack independently (exactly the old
    /// `std::thread::scope` shape, minus the spawns). The main thread
    /// takes chunk 0 and overlaps with the pool.
    fn forward_rows(
        &self,
        xs: &[f32],
        batch: usize,
        model: &MultiplierModel,
        scratch: &mut PlanScratch,
        out: &mut [f32],
        threads: usize,
    ) {
        let in_dim = self.input_dim();
        let out_dim = self.output_dim();
        let t = threads.min(batch);
        let chunk = batch.div_ceil(t);
        let PlanScratch { main, pool, .. } = scratch;
        pool.ensure(t - 1);
        // submit the workers' chunks first so they run while the main
        // thread computes its own
        let mut row0 = chunk;
        let mut used = 0usize;
        for worker in pool.workers[..t - 1].iter_mut() {
            let rows = chunk.min(batch - row0);
            if rows == 0 {
                break;
            }
            let mut cs = worker.scratch.take().expect("pool worker scratch in flight");
            cs.cur.clear();
            cs.cur.extend_from_slice(&xs[row0 * in_dim..(row0 + rows) * in_dim]);
            worker.cell.submit(ChunkJob {
                layers: std::sync::Arc::clone(&self.layers),
                kernel: self.kernel,
                model: *model,
                rows,
                task: JobTask::Stack,
                scratch: cs,
            });
            row0 += rows;
            used += 1;
        }
        let rows0 = chunk.min(batch);
        main.cur.clear();
        main.cur.extend_from_slice(&xs[..rows0 * in_dim]);
        run_chunk_in_place(&self.layers, self.kernel, rows0, model, main);
        out[..rows0 * out_dim].copy_from_slice(&main.cur);
        // reclaim in submission order (chunk boundaries recompute
        // deterministically)
        let mut row0 = rows0;
        for worker in pool.workers[..used].iter_mut() {
            let rows = chunk.min(batch - row0);
            let cs = worker.cell.await_done();
            out[row0 * out_dim..(row0 + rows) * out_dim].copy_from_slice(&cs.cur);
            worker.scratch = Some(cs);
            row0 += rows;
        }
    }

    /// Output-span tiling: per layer, the main thread quantizes the full
    /// activation once, every thread computes a disjoint span of output
    /// rows over the whole batch, and the dense span blocks are stitched
    /// into the layer output. Batch-1 latency scales with cores; each
    /// output element is still produced by exactly one thread.
    fn forward_outputs(
        &self,
        xs: &[f32],
        batch: usize,
        model: &MultiplierModel,
        scratch: &mut PlanScratch,
        out: &mut [f32],
        threads: usize,
    ) {
        let PlanScratch { main, span_out, pool } = scratch;
        main.cur.clear();
        main.cur.extend_from_slice(xs);
        for (li, layer) in self.layers.iter().enumerate() {
            let od = layer.out_dim;
            let t = threads.min(od);
            main.xq.clear();
            main.xq.extend(main.cur.iter().map(|&x| layer.x_quant.quantize(x)));
            if t == 1 {
                let span = 0..od;
                layer.gemm_rows_span(
                    &main.xq,
                    batch,
                    model,
                    &mut main.strips,
                    &mut main.next,
                    self.kernel,
                    span,
                );
                std::mem::swap(&mut main.cur, &mut main.next);
                continue;
            }
            pool.ensure(t - 1);
            let span = od.div_ceil(t);
            let mut r0 = span;
            let mut used = 0usize;
            for worker in pool.workers[..t - 1].iter_mut() {
                let len = span.min(od - r0);
                if len == 0 {
                    break;
                }
                let mut cs = worker.scratch.take().expect("pool worker scratch in flight");
                cs.xq.clear();
                cs.xq.extend_from_slice(&main.xq);
                worker.cell.submit(ChunkJob {
                    layers: std::sync::Arc::clone(&self.layers),
                    kernel: self.kernel,
                    model: *model,
                    rows: batch,
                    task: JobTask::Span { layer: li, r0, r1: r0 + len },
                    scratch: cs,
                });
                r0 += len;
                used += 1;
            }
            // main thread computes span 0 while the workers run
            let len0 = span.min(od);
            layer.gemm_rows_span(
                &main.xq,
                batch,
                model,
                &mut main.strips,
                span_out,
                self.kernel,
                0..len0,
            );
            main.next.clear();
            main.next.resize(batch * od, 0.0);
            for b in 0..batch {
                main.next[b * od..b * od + len0]
                    .copy_from_slice(&span_out[b * len0..(b + 1) * len0]);
            }
            let mut r0 = len0;
            for worker in pool.workers[..used].iter_mut() {
                let len = span.min(od - r0);
                let cs = worker.cell.await_done();
                for b in 0..batch {
                    main.next[b * od + r0..b * od + r0 + len]
                        .copy_from_slice(&cs.next[b * len..(b + 1) * len]);
                }
                worker.scratch = Some(cs);
                r0 += len;
            }
            std::mem::swap(&mut main.cur, &mut main.next);
        }
        out.copy_from_slice(&main.cur);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::multiplier::{MultiplierKind, MultiplierModel};
    use crate::util::Rng;

    fn random_layer(rng: &mut Rng, in_dim: usize, out_dim: usize, relu: bool) -> QuantLinear {
        let w: Vec<Vec<f32>> = (0..out_dim)
            .map(|_| (0..in_dim).map(|_| rng.gen_range_f32(-0.5, 0.5)).collect())
            .collect();
        let b: Vec<f32> = (0..out_dim).map(|_| rng.gen_range_f32(-0.1, 0.1)).collect();
        QuantLinear::from_float(&w, b, 1.0, relu)
    }

    #[test]
    fn plan_buckets_are_a_code_sorted_permutation() {
        let mut rng = Rng::seed_from_u64(3);
        let layer = random_layer(&mut rng, 19, 7, true);
        let plan = LayerPlan::compile(&layer);
        for r in 0..layer.out_dim {
            let row = &layer.wq[r * layer.in_dim..(r + 1) * layer.in_dim];
            let ro = &plan.offs[r * 17..r * 17 + 17];
            assert_eq!(ro[0] as usize, r * layer.in_dim);
            assert_eq!(ro[16] as usize, (r + 1) * layer.in_dim);
            let mut seen = vec![false; layer.in_dim];
            for w in 0..16 {
                assert!(ro[w] <= ro[w + 1], "offsets must be monotone");
                for &c in &plan.cols[ro[w] as usize..ro[w + 1] as usize] {
                    assert_eq!(row[c as usize], w as u8, "bucket {w} holds a foreign code");
                    assert!(!seen[c as usize], "column {c} listed twice");
                    seen[c as usize] = true;
                }
            }
            assert!(seen.iter().all(|&s| s), "every column appears exactly once");
        }
    }

    #[test]
    fn strip_matches_table_products() {
        let model = MultiplierModel::new(MultiplierKind::Approx2);
        let xrow: Vec<u8> = (0..16).collect();
        let mut strip = Vec::new();
        expand_strip(model.table(), &xrow, &mut strip);
        assert_eq!(strip.len(), 16 * xrow.len());
        for w in 0..16u8 {
            for (j, &x) in xrow.iter().enumerate() {
                assert_eq!(strip[w as usize * xrow.len() + j], model.mul(w, x) as i16);
            }
        }
        let mut strip32 = Vec::new();
        expand_strip32(model.table(), &xrow, &mut strip32);
        let widened: Vec<i32> = strip.iter().map(|&v| v as i32).collect();
        assert_eq!(strip32, widened, "the i32 strip must mirror the i16 strip");
    }

    #[test]
    fn planned_layer_matches_flat_gather_on_both_inner_paths() {
        let mut rng = Rng::seed_from_u64(11);
        // 23→9 takes the narrow-head fallback, 17→19 the strip path
        for (in_dim, out_dim) in [(23usize, 9usize), (17, 19)] {
            let mut layer = random_layer(&mut rng, in_dim, out_dim, false);
            layer.relu = true;
            let plan = LayerPlan::compile(&layer);
            assert_eq!(plan.uses_strip(), out_dim >= 16);
            let rows = 5;
            let xq: Vec<u8> = (0..rows * in_dim).map(|_| rng.gen_range_u64(0, 16) as u8).collect();
            for kind in MultiplierKind::ALL {
                let model = MultiplierModel::new(kind);
                let (mut flat, mut planned) = (Vec::new(), Vec::new());
                let mut scratch = StripScratch::default();
                layer.gemm_batch_into(&xq, rows, &model, &mut flat);
                plan.gemm_rows_into(&xq, rows, &model, &mut scratch, &mut planned);
                assert_eq!(planned, flat, "{kind} {in_dim}x{out_dim}");
            }
        }
    }

    #[test]
    fn threaded_plan_is_bit_exact_with_per_sample_forward() {
        let mlp = QuantMlp::random_for_study(8);
        let model = MultiplierModel::new(MultiplierKind::Approx);
        let batch = 7;
        let mut rng = Rng::seed_from_u64(21);
        let xs: Vec<f32> = (0..batch * 16).map(|_| rng.gen_range_f32(0.0, 1.0)).collect();
        for threads in [1usize, 2, 3, 16] {
            let plan = MlpPlan::compile(&mlp, threads);
            let got = plan.forward_batch(&xs, batch, &model);
            for b in 0..batch {
                let want = mlp.forward(&xs[b * 16..(b + 1) * 16], &model);
                assert_eq!(&got[b * 8..(b + 1) * 8], &want[..], "threads {threads} row {b}");
            }
        }
    }

    #[test]
    fn swar_segment_sum_matches_scalar_on_random_segments() {
        let mut rng = Rng::seed_from_u64(31);
        for len in [0usize, 1, 2, 3, 4, 5, 7, 8, 13, 64, 255, 256, 257, 1000] {
            let srow: Vec<i16> = (0..1024).map(|_| rng.gen_range_u64(0, 226) as i16).collect();
            let seg: Vec<u16> = (0..len).map(|_| rng.gen_range_u64(0, 1024) as u16).collect();
            assert_eq!(
                swar_segment_sum(&seg, &srow),
                scalar_segment_sum(&seg, &srow),
                "len {len}"
            );
        }
    }

    #[test]
    fn dispatched_simd_segment_sum_matches_scalar_on_random_segments() {
        // On an AVX2 x86_64 host this pins the AVX2 gather kernel; on
        // aarch64 the NEON kernel; elsewhere it degenerates to SWAR
        // (already pinned above) — the property holds everywhere.
        let kernel = GemmSimd::Auto.resolve();
        let mut rng = Rng::seed_from_u64(37);
        for len in [0usize, 1, 5, 7, 8, 9, 15, 16, 17, 63, 64, 255, 256, 1000] {
            let srow16: Vec<i16> = (0..1024).map(|_| rng.gen_range_u64(0, 256) as i16).collect();
            let seg: Vec<u16> = (0..len).map(|_| rng.gen_range_u64(0, 1024) as u16).collect();
            let want = scalar_segment_sum(&seg, &srow16);
            let got = match kernel {
                StripKernel::Scalar => scalar_segment_sum(&seg, &srow16),
                StripKernel::Swar => swar_segment_sum(&seg, &srow16),
                #[cfg(target_arch = "x86_64")]
                StripKernel::Avx2 => {
                    let srow32: Vec<i32> = srow16.iter().map(|&v| v as i32).collect();
                    simd::avx2_segment_sum(&seg, &srow32)
                }
                #[cfg(target_arch = "aarch64")]
                StripKernel::Neon => simd::neon_segment_sum(&seg, &srow16),
                #[allow(unreachable_patterns)]
                other => unreachable!("{other:?} cannot resolve on this host"),
            };
            assert_eq!(got, want, "{} len {len}", kernel.slug());
        }
    }

    #[test]
    fn forced_kernels_are_bit_identical_through_a_layer_plan() {
        let mut rng = Rng::seed_from_u64(59);
        for (in_dim, out_dim) in [(17usize, 19usize), (64, 32), (130, 16)] {
            let layer = random_layer(&mut rng, in_dim, out_dim, true);
            let plan = LayerPlan::compile(&layer);
            assert!(plan.uses_strip());
            let rows = 3;
            let xq: Vec<u8> = (0..rows * in_dim).map(|_| rng.gen_range_u64(0, 16) as u8).collect();
            for kind in MultiplierKind::ALL {
                let model = MultiplierModel::new(kind);
                let mut scratch = StripScratch::default();
                let mut reference = Vec::new();
                plan.gemm_rows_into_scalar(&xq, rows, &model, &mut scratch, &mut reference);
                for simd in GemmSimd::ALL {
                    let kernel = simd.resolve();
                    let mut got = Vec::new();
                    plan.gemm_rows_into_kernel(&xq, rows, &model, &mut scratch, &mut got, kernel);
                    assert_eq!(got, reference, "{kind} {in_dim}x{out_dim} {}", kernel.slug());
                }
            }
        }
    }

    #[test]
    fn swar_lanes_never_overflow_at_worst_case_products() {
        // 4096 columns of the worst legal table value 255 (approximate
        // multiplier tables are arbitrary u8s — exact ones cap at 225)
        // — the regime the flush cadence is sized for
        // (SWAR_FLUSH_EVERY · 255 < 2^16).
        let srow = vec![255i16; 4096];
        let seg: Vec<u16> = (0..4096).map(|c| c as u16).collect();
        assert_eq!(swar_segment_sum(&seg, &srow), 4096 * 255);
        // one past a flush boundary exercises the carry-over path
        let seg2 = &seg[..(SWAR_FLUSH_EVERY as usize * 4 + 5)];
        assert_eq!(swar_segment_sum(seg2, &srow), seg2.len() as i32 * 255);
    }

    #[test]
    fn simd_resolve_honors_forcing_and_falls_back() {
        assert_eq!(GemmSimd::Scalar.resolve(), StripKernel::Scalar);
        assert_eq!(GemmSimd::Swar.resolve(), StripKernel::Swar);
        // forcing an unavailable SIMD kernel falls back to SWAR rather
        // than dispatching an illegal instruction
        if !cfg!(target_arch = "x86_64") {
            assert_eq!(GemmSimd::Avx2.resolve(), StripKernel::Swar);
        }
        if !cfg!(target_arch = "aarch64") {
            assert_eq!(GemmSimd::Neon.resolve(), StripKernel::Swar);
        }
        // auto never picks a kernel whose guard does not hold here
        let auto = GemmSimd::Auto.resolve();
        match auto {
            StripKernel::Avx2 => assert!(cfg!(target_arch = "x86_64")),
            StripKernel::Neon => assert!(cfg!(target_arch = "aarch64")),
            StripKernel::Swar | StripKernel::Scalar => {}
        }
        assert!(!host_cpu_features().is_empty());
    }

    #[test]
    fn simd_and_partition_slugs_roundtrip() {
        for simd in GemmSimd::ALL {
            assert_eq!(GemmSimd::parse_slug(simd.slug()), Some(simd));
            assert_eq!(GemmSimd::from_arg(&simd.slug().to_uppercase()).unwrap(), simd);
        }
        assert!(GemmSimd::parse_slug("sse9").is_none());
        assert!(GemmSimd::from_arg("sse9").is_err());
        for part in GemmPartition::ALL {
            assert_eq!(GemmPartition::parse_slug(part.slug()), Some(part));
            assert_eq!(GemmPartition::from_arg(&part.slug().to_uppercase()).unwrap(), part);
        }
        assert!(GemmPartition::parse_slug("cols").is_none());
        assert!(GemmPartition::from_arg("cols").is_err());
    }

    #[test]
    fn output_span_tiling_is_bit_exact_with_per_sample_forward() {
        let mlp = QuantMlp::random_for_study(9);
        let model = MultiplierModel::new(MultiplierKind::Dnc);
        let mut rng = Rng::seed_from_u64(77);
        for threads in [2usize, 3, 5] {
            let plan = MlpPlan::compile_with(
                &mlp,
                GemmOptions { threads, simd: GemmSimd::Auto, partition: GemmPartition::Outputs },
            );
            let mut scratch = PlanScratch::default();
            for batch in [1usize, 2, 4] {
                let xs: Vec<f32> = (0..batch * 16).map(|_| rng.gen_range_f32(0.0, 1.0)).collect();
                let got = plan.forward_batch_with(&xs, batch, &model, &mut scratch);
                for b in 0..batch {
                    let want = mlp.forward(&xs[b * 16..(b + 1) * 16], &model);
                    assert_eq!(
                        &got[b * 8..(b + 1) * 8],
                        &want[..],
                        "threads {threads} batch {batch} row {b}"
                    );
                }
            }
        }
    }

    #[test]
    fn forward_batch_into_reuses_the_output_buffer() {
        let mlp = QuantMlp::random_for_study(15);
        let model = MultiplierModel::new(MultiplierKind::DncOpt);
        let plan = MlpPlan::compile(&mlp, 1);
        let mut scratch = PlanScratch::default();
        let mut out = Vec::new();
        for round in 0..3 {
            let batch = 2 + round;
            let xs: Vec<f32> = (0..batch * 16).map(|i| (i % 9) as f32 / 9.0).collect();
            plan.forward_batch_into(&xs, batch, &model, &mut scratch, &mut out);
            assert_eq!(out.len(), batch * 8);
            for b in 0..batch {
                let want = mlp.forward(&xs[b * 16..(b + 1) * 16], &model);
                assert_eq!(&out[b * 8..(b + 1) * 8], &want[..], "round {round} row {b}");
            }
        }
    }

    #[test]
    fn empty_batch_returns_empty_logits() {
        let plan = MlpPlan::compile(&QuantMlp::random_for_study(5), 4);
        let model = MultiplierModel::new(MultiplierKind::Ideal);
        assert!(plan.forward_batch(&[], 0, &model).is_empty());
    }

    #[test]
    fn zero_threads_resolves_to_available_parallelism() {
        assert!(resolve_threads(0) >= 1);
        assert_eq!(resolve_threads(3), 3);
        let plan = MlpPlan::compile(&QuantMlp::random_for_study(6), 0);
        assert!(plan.threads() >= 1);
    }

    #[test]
    fn scratch_reuse_across_batches_and_thread_counts_stays_exact() {
        let mlp = QuantMlp::random_for_study(13);
        let model = MultiplierModel::new(MultiplierKind::Dnc);
        for partition in GemmPartition::ALL {
            let plan = MlpPlan::compile_with(
                &mlp,
                GemmOptions { threads: 2, simd: GemmSimd::Auto, partition },
            );
            let mut scratch = PlanScratch::default();
            for round in 0..3 {
                let batch = 1 + round * 2; // exercises fan-out 1, 3, 5
                let xs: Vec<f32> = (0..batch * 16).map(|i| (i % 10) as f32 / 10.0).collect();
                let got = plan.forward_batch_with(&xs, batch, &model, &mut scratch);
                for b in 0..batch {
                    let want = mlp.forward(&xs[b * 16..(b + 1) * 16], &model);
                    assert_eq!(
                        &got[b * 8..(b + 1) * 8],
                        &want[..],
                        "{} round {round} row {b}",
                        partition.slug()
                    );
                }
            }
        }
    }

    #[test]
    fn pool_workers_persist_across_batches() {
        // the pool spawns on the first fan-out and is reused afterwards:
        // worker count never exceeds threads-1 no matter how many
        // batches run
        let mlp = QuantMlp::random_for_study(4);
        let model = MultiplierModel::new(MultiplierKind::Ideal);
        let plan = MlpPlan::compile(&mlp, 3);
        let mut scratch = PlanScratch::default();
        for _ in 0..4 {
            let xs: Vec<f32> = (0..6 * 16).map(|i| (i % 7) as f32 / 7.0).collect();
            let _ = plan.forward_batch_with(&xs, 6, &model, &mut scratch);
            assert!(scratch.pool.workers.len() <= 2, "pool must not grow past threads-1");
            assert!(
                scratch.pool.workers.iter().all(|w| w.scratch.is_some()),
                "every job's scratch must be reclaimed after the batch"
            );
        }
    }
}

/// Loom models of the pool handoff protocol (`ChunkCell`): the
/// submit → run → reclaim cycle and the stop-while-parked race, explored
/// over every interleaving. Run via the `loom` CI job
/// (`RUSTFLAGS="--cfg loom" cargo test --release --lib loom_models`).
#[cfg(all(test, loom))]
mod loom_models {
    use super::*;
    use crate::multiplier::{MultiplierKind, MultiplierModel};
    use crate::util::sync::Arc;

    /// A full handoff: the job (an empty layer stack, so pure protocol)
    /// must come back exactly once with its scratch intact, and stop
    /// must terminate the worker.
    #[test]
    fn pool_handoff_delivers_job_and_reclaims_scratch() {
        loom::model(|| {
            let cell = Arc::new(ChunkCell::new());
            let worker_cell = Arc::clone(&cell);
            let t = loom::thread::spawn(move || worker_loop(&worker_cell));
            let mut scratch = ChunkScratch::default();
            scratch.cur.extend_from_slice(&[1.0, 2.0]);
            cell.submit(ChunkJob {
                layers: std::sync::Arc::new(Vec::new()),
                kernel: StripKernel::Swar,
                model: MultiplierModel::new(MultiplierKind::Ideal),
                rows: 2,
                task: JobTask::Stack,
                scratch,
            });
            let back = cell.await_done();
            assert_eq!(back.cur, vec![1.0, 2.0]);
            cell.stop();
            t.join().unwrap();
        });
    }

    /// Stop racing a parked (or not-yet-parked) worker: the worker must
    /// observe `Stopped` and exit, never hang.
    #[test]
    fn pool_stop_always_wakes_the_worker() {
        loom::model(|| {
            let cell = Arc::new(ChunkCell::new());
            let worker_cell = Arc::clone(&cell);
            let t = loom::thread::spawn(move || worker_loop(&worker_cell));
            cell.stop();
            t.join().unwrap();
        });
    }
}
