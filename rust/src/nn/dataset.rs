//! Synthetic 8×8 digits dataset.
//!
//! The paper motivates 4–8 bit precision with "image and pattern
//! recognition applications" (§II, refs [24]–[26]). We use a deterministic
//! synthetic digits workload: 10 hand-drawn 8×8 glyphs perturbed by pixel
//! noise and ±1-pixel shifts. The same generator runs in
//! `python/compile/data.py` (same glyphs, same parametrization) so the
//! JAX-trained weights and the Rust runtime agree on the distribution;
//! the *test set* itself is exported by `aot.py` as `artifacts/testset.bin`
//! so evaluation bits match exactly.

use crate::util::Rng;

/// One labelled sample: 64 pixels in [0, 1], label 0..=9.
#[derive(Debug, Clone)]
pub struct Sample {
    pub pixels: Vec<f32>,
    pub label: usize,
}

/// The 10 glyphs, one string per digit, `#` = ink. Shared with the Python
/// generator (keep in sync with `python/compile/data.py`).
pub const GLYPHS: [&str; 10] = [
    // 0
    ".####...#..#...#..#...#..#...#..#...#..#...#..#...####..........",
    // 1
    "..##....###.....##......##......##......##......####............",
    // 2
    ".####...#..#......#.....##.....#......##......####.............",
    // 3
    ".####......#....###.......#.......#...#..#....###..............",
    // 4
    ".#..#...#..#...#..#...####......#.......#.......#...............",
    // 5
    ".####...#......###........#.......#...#..#....###..............",
    // 6
    "..###...#......####....#..#...#..#...#..#....###...............",
    // 7
    ".####......#.....#......#......#.......#.......#...............",
    // 8
    ".####...#..#....##.....#..#...#..#...#..#....####..............",
    // 9
    ".####...#..#...#..#....####.......#......#....##................",
];

/// Deterministic synthetic digits dataset.
#[derive(Debug, Clone)]
pub struct DigitsDataset {
    pub samples: Vec<Sample>,
}

impl DigitsDataset {
    /// Generate `per_digit` samples of each digit with the given seed.
    pub fn generate(per_digit: usize, seed: u64) -> Self {
        let mut rng = Rng::seed_from_u64(seed);
        let glyphs: Vec<Vec<f32>> = GLYPHS.iter().map(|g| glyph_pixels(g)).collect();
        let mut samples = Vec::with_capacity(per_digit * 10);
        for rep in 0..per_digit {
            for (label, glyph) in glyphs.iter().enumerate() {
                // ±1 pixel shift, pixel dropout and additive noise
                let dx = rng.gen_range_i64(-1, 2) as i32;
                let dy = rng.gen_range_i64(-1, 2) as i32;
                let mut pixels = vec![0.0f32; 64];
                for y in 0..8i32 {
                    for x in 0..8i32 {
                        let (sx, sy) = (x - dx, y - dy);
                        if (0..8).contains(&sx) && (0..8).contains(&sy) {
                            pixels[(y * 8 + x) as usize] = glyph[(sy * 8 + sx) as usize];
                        }
                    }
                }
                for p in pixels.iter_mut() {
                    if *p > 0.5 && rng.gen_bool(0.05) {
                        *p = 0.0; // dropout
                    }
                    *p = (*p + rng.gen_range_f32(-0.12, 0.12)).clamp(0.0, 1.0);
                }
                let _ = rep;
                samples.push(Sample { pixels, label });
            }
        }
        DigitsDataset { samples }
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    /// Classification accuracy of `classify` over the dataset.
    pub fn accuracy(&self, mut classify: impl FnMut(&[f32]) -> usize) -> f64 {
        let correct = self.samples.iter().filter(|s| classify(&s.pixels) == s.label).count();
        correct as f64 / self.samples.len() as f64
    }

    /// Parse the raw binary test set exported by `aot.py`
    /// (`artifacts/testset.bin`): `u32 n`, then per sample 64 `f32` pixels
    /// (LE) + `u32` label.
    pub fn from_binary(bytes: &[u8]) -> crate::Result<Self> {
        anyhow::ensure!(bytes.len() >= 4, "truncated testset");
        let n = u32::from_le_bytes(bytes[0..4].try_into().unwrap()) as usize;
        let rec = 64 * 4 + 4;
        anyhow::ensure!(bytes.len() == 4 + n * rec, "testset length mismatch");
        let mut samples = Vec::with_capacity(n);
        for i in 0..n {
            let base = 4 + i * rec;
            let pixels: Vec<f32> = (0..64)
                .map(|j| {
                    let o = base + j * 4;
                    f32::from_le_bytes(bytes[o..o + 4].try_into().unwrap())
                })
                .collect();
            let label =
                u32::from_le_bytes(bytes[base + 256..base + 260].try_into().unwrap()) as usize;
            anyhow::ensure!(label < 10, "label out of range");
            samples.push(Sample { pixels, label });
        }
        Ok(DigitsDataset { samples })
    }

    /// Serialize in the same binary format (round-trip with
    /// [`DigitsDataset::from_binary`], also used by tests).
    pub fn to_binary(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(4 + self.samples.len() * 260);
        out.extend((self.samples.len() as u32).to_le_bytes());
        for s in &self.samples {
            for &p in &s.pixels {
                out.extend(p.to_le_bytes());
            }
            out.extend((s.label as u32).to_le_bytes());
        }
        out
    }
}

fn glyph_pixels(g: &str) -> Vec<f32> {
    let mut px: Vec<f32> = g.chars().map(|c| if c == '#' { 1.0 } else { 0.0 }).collect();
    px.resize(64, 0.0);
    px
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generation_is_deterministic() {
        let a = DigitsDataset::generate(3, 11);
        let b = DigitsDataset::generate(3, 11);
        assert_eq!(a.len(), 30);
        for (x, y) in a.samples.iter().zip(b.samples.iter()) {
            assert_eq!(x.label, y.label);
            assert_eq!(x.pixels, y.pixels);
        }
    }

    #[test]
    fn different_seeds_differ() {
        let a = DigitsDataset::generate(1, 1);
        let b = DigitsDataset::generate(1, 2);
        assert!(a.samples.iter().zip(b.samples.iter()).any(|(x, y)| x.pixels != y.pixels));
    }

    #[test]
    fn pixels_in_unit_range() {
        let d = DigitsDataset::generate(5, 3);
        assert!(d.samples.iter().flat_map(|s| &s.pixels).all(|&p| (0.0..=1.0).contains(&p)));
    }

    #[test]
    fn binary_roundtrip() {
        let d = DigitsDataset::generate(2, 9);
        let back = DigitsDataset::from_binary(&d.to_binary()).unwrap();
        assert_eq!(back.len(), d.len());
        for (a, b) in d.samples.iter().zip(back.samples.iter()) {
            assert_eq!(a.label, b.label);
            assert_eq!(a.pixels, b.pixels);
        }
    }

    #[test]
    fn glyphs_are_distinct() {
        let g: Vec<Vec<f32>> = GLYPHS.iter().map(|s| glyph_pixels(s)).collect();
        for i in 0..10 {
            for j in (i + 1)..10 {
                assert_ne!(g[i], g[j], "glyphs {i} and {j} identical");
            }
        }
    }
}
