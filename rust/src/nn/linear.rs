//! Quantized linear layer whose scalar products go through a LUT
//! multiplier configuration.

use super::Quantizer;
use crate::multiplier::MultiplierModel;

/// A linear layer `y = W·x + b` in 4-bit integer arithmetic.
///
/// Weights are stored as unsigned 4-bit codes with zero-point 8; inputs
/// as unsigned 4-bit codes with zero-point 0. The MAC per output is
///
/// ```text
/// acc_i = Σ_j LUT(wq_ij, xq_j) − 8 · Σ_j xq_j
/// y_i   = acc_i · w_scale · x_scale + b_i
/// ```
///
/// where `LUT` is the configured multiplier — the only place approximation
/// enters. The zero-point correction `8·Σxq` is exact integer arithmetic
/// (an adder tree in hardware, outside the LUNA unit).
#[derive(Debug, Clone)]
pub struct QuantLinear {
    /// `out_dim × in_dim`, row-major 4-bit codes.
    pub wq: Vec<u8>,
    pub in_dim: usize,
    pub out_dim: usize,
    pub w_quant: Quantizer,
    pub x_quant: Quantizer,
    pub bias: Vec<f32>,
    /// Apply ReLU after the affine output.
    pub relu: bool,
}

impl QuantLinear {
    /// Quantize float weights `[out][in]` into a layer.
    pub fn from_float(
        w: &[Vec<f32>],
        bias: Vec<f32>,
        x_max_abs: f32,
        relu: bool,
    ) -> Self {
        assert!(!w.is_empty(), "QuantLinear::from_float: weight matrix has no rows (out_dim = 0)");
        let out_dim = w.len();
        let in_dim = w[0].len();
        assert!(in_dim > 0, "QuantLinear::from_float: weight rows are empty (in_dim = 0)");
        assert!(w.iter().all(|r| r.len() == in_dim), "weight rows must all have length {in_dim}");
        assert_eq!(bias.len(), out_dim);
        let w_max = w.iter().flatten().fold(0.0f32, |m, &v| m.max(v.abs()));
        let w_quant = Quantizer::for_weights(w_max);
        let x_quant = Quantizer::for_activations(x_max_abs);
        let wq = w.iter().flat_map(|row| row.iter().map(|&v| w_quant.quantize(v))).collect();
        QuantLinear { wq, in_dim, out_dim, w_quant, x_quant, bias, relu }
    }

    /// Build directly from quantized codes (artifact loading path).
    pub fn from_codes(
        wq: Vec<u8>,
        in_dim: usize,
        out_dim: usize,
        w_quant: Quantizer,
        x_quant: Quantizer,
        bias: Vec<f32>,
        relu: bool,
    ) -> Self {
        assert_eq!(wq.len(), in_dim * out_dim);
        assert!(wq.iter().all(|&q| q < 16), "codes must be 4-bit");
        assert_eq!(bias.len(), out_dim);
        QuantLinear { wq, in_dim, out_dim, w_quant, x_quant, bias, relu }
    }

    /// Integer accumulators before dequantization — the values the LUNA
    /// bank produces. Exposed for bit-accuracy cross-checks.
    pub fn accumulate(&self, xq: &[u8], model: &MultiplierModel) -> Vec<i32> {
        assert_eq!(xq.len(), self.in_dim);
        let x_sum: i32 = xq.iter().map(|&x| x as i32).sum();
        let zp = self.w_quant.zero_point as i32;
        (0..self.out_dim)
            .map(|i| {
                let row = &self.wq[i * self.in_dim..(i + 1) * self.in_dim];
                let lut: i32 = row
                    .iter()
                    .zip(xq)
                    .map(|(&w, &x)| model.mul(w, x) as i32)
                    .sum();
                lut - zp * x_sum
            })
            .collect()
    }

    /// Full forward: quantize input, integer MAC, dequantize, bias, ReLU.
    pub fn forward(&self, x: &[f32], model: &MultiplierModel) -> Vec<f32> {
        let xq = self.x_quant.quantize_slice(x);
        let acc = self.accumulate(&xq, model);
        acc.iter()
            .zip(&self.bias)
            .map(|(&a, &b)| {
                let v = a as f32 * self.w_quant.scale * self.x_quant.scale + b;
                if self.relu {
                    v.max(0.0)
                } else {
                    v
                }
            })
            .collect()
    }

    /// Number of 4b×4b multiplies one forward pass performs (what the
    /// coordinator charges to LUNA units).
    pub fn macs(&self) -> u64 {
        (self.in_dim * self.out_dim) as u64
    }

    /// Compile this layer's static weight codes into the planned-kernel
    /// representation (see [`super::LayerPlan`]).
    pub fn plan(&self) -> super::LayerPlan {
        super::LayerPlan::compile(self)
    }

    /// Batched LUT-GEMM over pre-quantized activations.
    ///
    /// `xq` is row-major `batch × in_dim` 4-bit codes; writes row-major
    /// `batch × out_dim` dequantized (bias + ReLU applied) activations
    /// into `out`, clearing it first. The inner loop is a flat gather
    /// from the 256-entry product table with the zero-point correction
    /// `zp · Σ_j xq_j` hoisted out per input row — the whole batch pays
    /// one correction sum per row instead of one per MAC.
    ///
    /// Bit-exact with the per-sample path: the accumulation order, the
    /// LUT contents and the dequantization expression are identical to
    /// [`QuantLinear::accumulate`] + [`QuantLinear::forward`].
    pub fn gemm_batch_into(
        &self,
        xq: &[u8],
        batch: usize,
        model: &MultiplierModel,
        out: &mut Vec<f32>,
    ) {
        assert_eq!(xq.len(), batch * self.in_dim, "bad batch input shape");
        out.clear();
        out.reserve(batch * self.out_dim);
        let table = model.table();
        let zp = self.w_quant.zero_point as i32;
        for b in 0..batch {
            let xrow = &xq[b * self.in_dim..(b + 1) * self.in_dim];
            let corr = zp * xrow.iter().map(|&x| x as i32).sum::<i32>();
            for i in 0..self.out_dim {
                let wrow = &self.wq[i * self.in_dim..(i + 1) * self.in_dim];
                let lut: i32 = wrow
                    .iter()
                    .zip(xrow)
                    .map(|(&w, &x)| table[((w as usize) << 4) | x as usize] as i32)
                    .sum();
                let a = lut - corr;
                let v = a as f32 * self.w_quant.scale * self.x_quant.scale + self.bias[i];
                out.push(if self.relu { v.max(0.0) } else { v });
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::multiplier::{MultiplierKind, MultiplierModel};

    fn toy_layer() -> QuantLinear {
        QuantLinear::from_float(
            &[vec![0.5, -0.25, 0.1], vec![-0.4, 0.3, 0.2]],
            vec![0.05, -0.1],
            1.0,
            false,
        )
    }

    #[test]
    fn ideal_forward_approximates_float_matmul() {
        let l = toy_layer();
        let model = MultiplierModel::new(MultiplierKind::Ideal);
        let x = vec![0.8, 0.2, 0.5];
        let y = l.forward(&x, &model);
        let expect = [
            0.5 * 0.8 - 0.25 * 0.2 + 0.1 * 0.5 + 0.05,
            -0.4 * 0.8 + 0.3 * 0.2 + 0.2 * 0.5 - 0.1,
        ];
        for (got, want) in y.iter().zip(expect.iter()) {
            assert!((got - want).abs() < 0.15, "got {got} want {want}");
        }
    }

    #[test]
    fn exact_lut_configs_agree_with_ideal() {
        let l = toy_layer();
        let x = vec![0.3, 0.9, 0.1];
        let ideal = l.forward(&x, &MultiplierModel::new(MultiplierKind::Ideal));
        for kind in [MultiplierKind::Dnc, MultiplierKind::DncOpt, MultiplierKind::Traditional] {
            let y = l.forward(&x, &MultiplierModel::new(kind));
            assert_eq!(y, ideal, "{kind}");
        }
    }

    #[test]
    fn relu_clamps_negative() {
        let mut l = toy_layer();
        l.relu = true;
        let y = l.forward(&[1.0, 1.0, 0.0], &MultiplierModel::new(MultiplierKind::Ideal));
        assert!(y.iter().all(|&v| v >= 0.0));
    }

    #[test]
    fn accumulate_is_integer_exact_for_ideal() {
        let l = toy_layer();
        let xq = vec![12u8, 3, 7];
        let acc = l.accumulate(&xq, &MultiplierModel::new(MultiplierKind::Ideal));
        // manual: row0 codes
        let row0: Vec<i32> = l.wq[0..3].iter().map(|&w| w as i32).collect();
        let manual: i32 =
            row0.iter().zip(&xq).map(|(&w, &x)| w * x as i32).sum::<i32>() - 8 * (12 + 3 + 7);
        assert_eq!(acc[0], manual);
    }

    #[test]
    #[should_panic]
    fn wrong_input_width_panics() {
        let l = toy_layer();
        let _ = l.forward(&[1.0], &MultiplierModel::new(MultiplierKind::Ideal));
    }

    #[test]
    #[should_panic(expected = "weight matrix has no rows")]
    fn empty_weight_matrix_panics_with_context() {
        let _ = QuantLinear::from_float(&[], vec![], 1.0, false);
    }

    #[test]
    #[should_panic(expected = "weight rows are empty")]
    fn empty_weight_rows_panic_with_context() {
        let _ = QuantLinear::from_float(&[vec![], vec![]], vec![0.0, 0.0], 1.0, false);
    }

    #[test]
    fn gemm_batch_is_bit_exact_with_per_sample_forward() {
        let mut l = toy_layer();
        l.relu = true;
        let rows: [&[f32]; 3] = [&[0.8, 0.2, 0.5], &[0.0, 1.0, 0.3], &[0.6, 0.6, 0.9]];
        for kind in MultiplierKind::ALL {
            let model = MultiplierModel::new(kind);
            let mut xq = Vec::new();
            for r in rows {
                xq.extend(l.x_quant.quantize_slice(r));
            }
            let mut out = Vec::new();
            l.gemm_batch_into(&xq, rows.len(), &model, &mut out);
            for (b, r) in rows.iter().enumerate() {
                let want = l.forward(r, &model);
                assert_eq!(&out[b * l.out_dim..(b + 1) * l.out_dim], &want[..], "{kind}");
            }
        }
    }
}
