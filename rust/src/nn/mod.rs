//! Quantized neural-network substrate (bit-accurate functional model).
//!
//! The paper motivates LUNA-CiM with neural acceleration: 4-bit weights ×
//! 4-bit activations through the LUT multipliers (§I, §IV.A). This module
//! is the Rust-side functional model of exactly that arithmetic:
//!
//! * [`Quantizer`] — affine 4-bit quantization;
//! * [`QuantLinear`] / [`QuantMlp`] — integer MACs where **every scalar
//!   product goes through a [`MultiplierModel`]** (exact or approximate),
//!   matching the Pallas kernel's semantics bit-for-bit (cross-checked in
//!   integration tests against the AOT artifacts); both per-sample
//!   ([`QuantMlp::forward`]) and batched flat-gather LUT-GEMM
//!   ([`QuantMlp::forward_batch`], bit-exact with the former) paths;
//! * [`LayerPlan`] / [`MlpPlan`] — the *planned* LUT-GEMM kernel the
//!   execution backends run: weights compiled once into code-sorted
//!   column buckets, the product table expanded into a per-input-row LUT
//!   strip summed by a runtime-dispatched kernel ([`GemmSimd`]:
//!   scalar/SWAR/AVX2/NEON), and batches tiled across a persistent
//!   worker pool by rows or output spans ([`GemmPartition`]) — bit-exact
//!   with the paths above for every kernel, tiling mode and thread
//!   count;
//! * [`DigitsDataset`] — the synthetic 8×8 digits workload used by the
//!   examples and the end-to-end serving driver.
//!
//! [`MultiplierModel`]: crate::multiplier::MultiplierModel

mod dataset;
mod gemm;
mod linear;
mod mlp;
mod quant;

pub use dataset::{DigitsDataset, Sample};
pub use gemm::{
    host_cpu_features, resolve_threads, GemmOptions, GemmPartition, GemmSimd, LayerPlan, MlpPlan,
    PlanScratch, StripKernel, StripScratch,
};
pub use linear::QuantLinear;
pub use mlp::{BatchScratch, QuantMlp};
pub use quant::Quantizer;

/// Index of the maximum element (ties -> first).
pub fn argmax(xs: &[f32]) -> usize {
    let mut best = 0usize;
    for (i, &v) in xs.iter().enumerate().skip(1) {
        if v > xs[best] {
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    #[test]
    fn argmax_basic() {
        assert_eq!(super::argmax(&[0.1, 3.0, 2.0]), 1);
        assert_eq!(super::argmax(&[5.0, 5.0]), 0);
    }
}
