//! Affine 4-bit quantization (unsigned codes 0..=15).


/// Uniform affine quantizer to 4-bit unsigned codes.
///
/// `q = clamp(round(x / scale) + zero_point, 0, 15)`,
/// `x ≈ (q − zero_point) · scale`.
///
/// Activations use `zero_point = 0` (ReLU outputs are non-negative);
/// weights use `zero_point = 8` so signed weights map onto the unsigned
/// 4-bit codes the LUT multipliers consume (§ the D&C LUT stores products
/// of *unsigned* 4-bit operands; the zero-point correction is exact
/// integer arithmetic outside the LUT).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Quantizer {
    pub scale: f32,
    pub zero_point: u8,
}

impl Quantizer {
    pub fn new(scale: f32, zero_point: u8) -> Self {
        assert!(scale > 0.0, "scale must be positive");
        assert!(zero_point < 16);
        Quantizer { scale, zero_point }
    }

    /// Activation quantizer calibrated so `max_abs` maps to code 15.
    pub fn for_activations(max_abs: f32) -> Self {
        Quantizer::new((max_abs.max(1e-6)) / 15.0, 0)
    }

    /// Weight quantizer calibrated so ±`max_abs` fits codes 0..=15 around
    /// the zero-point 8.
    pub fn for_weights(max_abs: f32) -> Self {
        Quantizer::new((max_abs.max(1e-6)) / 7.0, 8)
    }

    pub fn quantize(&self, x: f32) -> u8 {
        let q = (x / self.scale).round() + self.zero_point as f32;
        q.clamp(0.0, 15.0) as u8
    }

    pub fn dequantize(&self, q: u8) -> f32 {
        (q as i32 - self.zero_point as i32) as f32 * self.scale
    }

    pub fn quantize_slice(&self, xs: &[f32]) -> Vec<u8> {
        xs.iter().map(|&x| self.quantize(x)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_error_bounded_by_half_scale() {
        let q = Quantizer::for_activations(1.0);
        for i in 0..=100 {
            let x = i as f32 / 100.0;
            let err = (q.dequantize(q.quantize(x)) - x).abs();
            assert!(err <= q.scale / 2.0 + 1e-6, "x={x} err={err}");
        }
    }

    #[test]
    fn weights_map_sign_symmetrically() {
        let q = Quantizer::for_weights(0.7);
        assert_eq!(q.quantize(0.0), 8);
        assert!(q.quantize(-0.7) <= 1);
        assert_eq!(q.quantize(0.7), 15);
        assert!((q.dequantize(q.quantize(-0.7)) - -0.7).abs() < q.scale);
    }

    #[test]
    fn clamps_out_of_range() {
        let q = Quantizer::for_activations(1.0);
        assert_eq!(q.quantize(50.0), 15);
        assert_eq!(q.quantize(-3.0), 0);
    }

    #[test]
    #[should_panic]
    fn zero_scale_rejected() {
        let _ = Quantizer::new(0.0, 0);
    }
}
