//! Quantized multi-layer perceptron over LUT multipliers.

use super::{QuantLinear, Quantizer};
use crate::multiplier::MultiplierModel;
use crate::util::{kv, Rng};
use crate::Result;
use anyhow::{ensure, Context};
use std::fmt::Write as _;

/// Reusable scratch buffers for [`QuantMlp::forward_batch_with`]: one
/// quantized-code buffer plus two activation buffers that ping-pong
/// across layers, so steady-state batched inference allocates nothing
/// but the returned logits.
#[derive(Debug, Default)]
pub struct BatchScratch {
    xq: Vec<u8>,
    cur: Vec<f32>,
    next: Vec<f32>,
}

/// An MLP whose every MAC routes through a configurable LUT multiplier.
#[derive(Debug, Clone)]
pub struct QuantMlp {
    pub layers: Vec<QuantLinear>,
}

impl QuantMlp {
    pub fn new(layers: Vec<QuantLinear>) -> Self {
        assert!(!layers.is_empty());
        for pair in layers.windows(2) {
            assert_eq!(pair[0].out_dim, pair[1].in_dim, "layer dims must chain");
        }
        QuantMlp { layers }
    }

    pub fn input_dim(&self) -> usize {
        self.layers[0].in_dim
    }

    pub fn output_dim(&self) -> usize {
        self.layers.last().unwrap().out_dim
    }

    /// Total 4b×4b MACs per forward pass.
    pub fn macs(&self) -> u64 {
        self.layers.iter().map(|l| l.macs()).sum()
    }

    /// Approximate heap footprint of the model's owned buffers (weight
    /// codes + biases) — one input to the serving plan cache's byte
    /// budget (see `crate::engine::PlanCache`).
    pub fn heap_bytes(&self) -> usize {
        self.layers
            .iter()
            .map(|l| l.wq.len() + l.bias.len() * std::mem::size_of::<f32>())
            .sum()
    }

    /// Compile the planned LUT-GEMM kernel for this model: code-sorted
    /// weight plans per layer plus batch tiling across up to `threads`
    /// GEMM threads (`0` = one per available core). The execution
    /// backends build this once at construction; it is bit-exact with
    /// [`QuantMlp::forward`] for every thread count (see
    /// [`super::MlpPlan`]).
    pub fn plan(&self, threads: usize) -> super::MlpPlan {
        super::MlpPlan::compile(self, threads)
    }

    /// [`QuantMlp::plan`] with the full `gemm.*` knob set: thread cap,
    /// strip-kernel choice (`gemm.simd`, dispatched against this host at
    /// compile time) and tiling mode (`gemm.partition`). Every
    /// combination is bit-exact with [`QuantMlp::forward`].
    pub fn plan_with(&self, opts: super::GemmOptions) -> super::MlpPlan {
        super::MlpPlan::compile_with(self, opts)
    }

    /// Forward pass under the given multiplier configuration.
    pub fn forward(&self, x: &[f32], model: &MultiplierModel) -> Vec<f32> {
        let mut h = x.to_vec();
        for layer in &self.layers {
            h = layer.forward(&h, model);
        }
        h
    }

    /// Classify: forward + argmax.
    pub fn classify(&self, x: &[f32], model: &MultiplierModel) -> usize {
        super::argmax(&self.forward(x, model))
    }

    /// Batched forward pass: `xs` is row-major `batch × input_dim`;
    /// returns row-major `batch × output_dim` logits.
    ///
    /// Per layer the whole batch is quantized once, then run through the
    /// flat-gather LUT-GEMM ([`QuantLinear::gemm_batch_into`]). Bit-exact
    /// with calling [`QuantMlp::forward`] on each row (the native
    /// backend's equivalence test covers every [`MultiplierKind`]).
    ///
    /// [`MultiplierKind`]: crate::multiplier::MultiplierKind
    pub fn forward_batch(&self, xs: &[f32], batch: usize, model: &MultiplierModel) -> Vec<f32> {
        let mut scratch = BatchScratch::default();
        self.forward_batch_with(xs, batch, model, &mut scratch)
    }

    /// [`QuantMlp::forward_batch`] with caller-owned scratch buffers so a
    /// long-lived worker reuses its allocations across batches and layers.
    pub fn forward_batch_with(
        &self,
        xs: &[f32],
        batch: usize,
        model: &MultiplierModel,
        scratch: &mut BatchScratch,
    ) -> Vec<f32> {
        assert_eq!(xs.len(), batch * self.input_dim(), "bad batch input shape");
        let BatchScratch { xq, cur, next } = scratch;
        cur.clear();
        cur.extend_from_slice(xs);
        for layer in &self.layers {
            xq.clear();
            xq.extend(cur.iter().map(|&x| layer.x_quant.quantize(x)));
            layer.gemm_batch_into(xq, batch, model, next);
            std::mem::swap(cur, next);
        }
        cur.clone()
    }

    /// Random small MLP for the Fig 13 MAE study (16 → 12 → 8), with
    /// activation ranges chosen so intermediate values stay in range.
    pub fn random_for_study(seed: u64) -> Self {
        let mut rng = Rng::seed_from_u64(seed);
        let mut layer = |i: usize, o: usize, x_max: f32, relu: bool| {
            let w: Vec<Vec<f32>> = (0..o)
                .map(|_| (0..i).map(|_| rng.gen_range_f32(-0.5, 0.5)).collect())
                .collect();
            let b: Vec<f32> = (0..o).map(|_| rng.gen_range_f32(-0.1, 0.1)).collect();
            QuantLinear::from_float(&w, b, x_max, relu)
        };
        QuantMlp::new(vec![layer(16, 12, 1.0, true), layer(12, 8, 3.0, false)])
    }

    /// The paper-shaped digits classifier architecture (64 → 32 → 10),
    /// randomly initialized (training happens in JAX at build time; this
    /// is used by tests and the untrained baseline).
    pub fn random_digits(seed: u64) -> Self {
        let mut rng = Rng::seed_from_u64(seed);
        let mut layer = |i: usize, o: usize, x_max: f32, relu: bool| {
            let w: Vec<Vec<f32>> = (0..o)
                .map(|_| (0..i).map(|_| rng.gen_range_f32(-0.3, 0.3)).collect())
                .collect();
            let b: Vec<f32> = (0..o).map(|_| rng.gen_range_f32(-0.05, 0.05)).collect();
            QuantLinear::from_float(&w, b, x_max, relu)
        };
        QuantMlp::new(vec![layer(64, 32, 1.0, true), layer(32, 10, 4.0, false)])
    }

    /// Serialize to the `weights.txt` artifact format shared with
    /// `python/compile/aot.py` (kv lines; see [`crate::util::kv`]).
    pub fn to_text(&self) -> String {
        let mut m = kv::KvMap::new();
        m.set("format", "luna-mlp-v1");
        m.set("layers", self.layers.len());
        for (i, l) in self.layers.iter().enumerate() {
            m.set(&format!("layer{i}.in"), l.in_dim);
            m.set(&format!("layer{i}.out"), l.out_dim);
            m.set(&format!("layer{i}.relu"), if l.relu { 1 } else { 0 });
            m.set(&format!("layer{i}.w_scale"), l.w_quant.scale);
            m.set(&format!("layer{i}.w_zp"), l.w_quant.zero_point);
            m.set(&format!("layer{i}.x_scale"), l.x_quant.scale);
            m.set(&format!("layer{i}.x_zp"), l.x_quant.zero_point);
            let mut bias = String::new();
            for b in &l.bias {
                let _ = write!(bias, "{b} ");
            }
            m.set(&format!("layer{i}.bias"), bias.trim());
            let mut codes = String::new();
            for c in &l.wq {
                let _ = write!(codes, "{c} ");
            }
            m.set(&format!("layer{i}.wq"), codes.trim());
        }
        m.render()
    }

    /// Load from the artifact text written by [`QuantMlp::to_text`] or by
    /// `python/compile/aot.py` (`artifacts/weights.txt`).
    pub fn from_text(s: &str) -> Result<Self> {
        let m = kv::KvMap::parse(s)?;
        ensure!(m.get("format")? == "luna-mlp-v1", "unknown weights format");
        let n = m.get_usize("layers")?;
        ensure!(n >= 1, "no layers");
        let mut layers = Vec::with_capacity(n);
        for i in 0..n {
            let in_dim = m.get_usize(&format!("layer{i}.in"))?;
            let out_dim = m.get_usize(&format!("layer{i}.out"))?;
            let relu = m.get_usize(&format!("layer{i}.relu"))? != 0;
            let w_quant = Quantizer::new(
                m.get_f32(&format!("layer{i}.w_scale"))?,
                m.get_usize(&format!("layer{i}.w_zp"))? as u8,
            );
            let x_quant = Quantizer::new(
                m.get_f32(&format!("layer{i}.x_scale"))?,
                m.get_usize(&format!("layer{i}.x_zp"))? as u8,
            );
            let bias = kv::parse_floats(m.get(&format!("layer{i}.bias"))?)
                .with_context(|| format!("layer {i} bias"))?;
            let wq = kv::parse_codes(m.get(&format!("layer{i}.wq"))?, true)
                .with_context(|| format!("layer {i} weight codes"))?;
            ensure!(wq.len() == in_dim * out_dim, "layer {i} weight shape mismatch");
            ensure!(bias.len() == out_dim, "layer {i} bias shape mismatch");
            layers.push(QuantLinear::from_codes(wq, in_dim, out_dim, w_quant, x_quant, bias, relu));
        }
        Ok(QuantMlp::new(layers))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::multiplier::{MultiplierKind, MultiplierModel};

    #[test]
    fn forward_runs_and_has_right_dims() {
        let mlp = QuantMlp::random_for_study(1);
        let y = mlp.forward(&vec![0.5; 16], &MultiplierModel::new(MultiplierKind::Ideal));
        assert_eq!(y.len(), 8);
        assert_eq!(mlp.input_dim(), 16);
        assert_eq!(mlp.macs(), 16 * 12 + 12 * 8);
    }

    #[test]
    fn text_roundtrip_preserves_outputs() {
        let mlp = QuantMlp::random_for_study(2);
        let clone = QuantMlp::from_text(&mlp.to_text()).unwrap();
        let x: Vec<f32> = (0..16).map(|i| i as f32 / 16.0).collect();
        let m = MultiplierModel::new(MultiplierKind::DncOpt);
        assert_eq!(mlp.forward(&x, &m), clone.forward(&x, &m));
    }

    #[test]
    fn malformed_text_rejected() {
        assert!(QuantMlp::from_text("format nope\nlayers 1\n").is_err());
        let mlp = QuantMlp::random_for_study(3);
        let bad = mlp.to_text().replace("luna-mlp-v1", "luna-mlp-v9");
        assert!(QuantMlp::from_text(&bad).is_err());
    }

    #[test]
    #[should_panic]
    fn mismatched_layer_dims_panic() {
        let a = QuantLinear::from_float(&[vec![0.1; 4]], vec![0.0], 1.0, true);
        let b = QuantLinear::from_float(&[vec![0.1; 3]], vec![0.0], 1.0, false);
        let _ = QuantMlp::new(vec![a, b]);
    }

    #[test]
    fn forward_batch_matches_per_sample_forward_for_all_kinds() {
        let mlp = QuantMlp::random_for_study(9);
        let batch = 5;
        let mut rng = crate::util::Rng::seed_from_u64(42);
        let xs: Vec<f32> = (0..batch * 16).map(|_| rng.gen_range_f32(0.0, 1.0)).collect();
        let mut scratch = super::BatchScratch::default();
        for kind in MultiplierKind::ALL {
            let model = MultiplierModel::new(kind);
            let got = mlp.forward_batch_with(&xs, batch, &model, &mut scratch);
            assert_eq!(got.len(), batch * mlp.output_dim());
            for b in 0..batch {
                let want = mlp.forward(&xs[b * 16..(b + 1) * 16], &model);
                assert_eq!(&got[b * 8..(b + 1) * 8], &want[..], "{kind} row {b}");
            }
        }
    }

    #[test]
    fn forward_batch_handles_empty_batch() {
        let mlp = QuantMlp::random_for_study(4);
        let model = MultiplierModel::new(MultiplierKind::DncOpt);
        assert!(mlp.forward_batch(&[], 0, &model).is_empty());
    }

    #[test]
    fn approx_configs_change_but_dont_destroy_outputs() {
        let mlp = QuantMlp::random_for_study(3);
        let x = vec![0.4; 16];
        let ideal = mlp.forward(&x, &MultiplierModel::new(MultiplierKind::Ideal));
        let approx = mlp.forward(&x, &MultiplierModel::new(MultiplierKind::Approx2));
        assert_ne!(ideal, approx);
        assert_eq!(ideal.len(), approx.len());
        assert!(approx.iter().all(|v| v.is_finite()));
    }
}
