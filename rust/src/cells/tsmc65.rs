//! Calibrated 65 nm-like cell library.
//!
//! Transistor counts are textbook static-CMOS values (what the paper counts
//! "employing the TSMC 65 nm digital library as a reference"). Area, energy
//! and delay constants are **calibrated** to the paper's reported
//! aggregates rather than copied from a (proprietary) PDK:
//!
//! * optimized-D&C LUNA unit (10 SRAM + 36 MUX2 + 3 HA + 3 FA), routed →
//!   **287 µm²** (Fig 18);
//! * 8×8 SRAM array + periphery, routed → **≈2502 µm²**, so that the array
//!   plus four LUNA units totals **3650 µm²** with a **32 %** overhead
//!   (Fig 18);
//! * array write energy **173.8 pJ/bit/access** with the Fig 15 component
//!   breakdown; per-toggle logic energies scaled so the measured
//!   switching activity of the optimized-D&C unit under the paper's
//!   SSIV.B stimulus lands on **47.96 fJ/op** (0.0276 % share).
//!
//! Every reproduced claim is a *ratio over this one library*, so the
//! calibration does not beg the questions the paper answers (which config
//! is smaller / cheaper, and by what factor).

use super::{CellKind, CellLibrary, CellParams};

/// Supply voltage (65 nm nominal).
pub const VDD: f64 = 1.2;

/// Routing/whitespace factor. Calibrated so the optimized-D&C unit's placed
/// area (242.25 µm²) routes to the paper's 287 µm².
pub const ROUTING_OVERHEAD: f64 = 287.0 / 242.25;

/// Build the calibrated 65 nm-like library.
pub fn tsmc65_library() -> CellLibrary {
    CellLibrary::from_fn("tsmc65-like", VDD, ROUTING_OVERHEAD, |kind| match kind {
        // transistors, area µm², fJ/toggle, leak nW, delay ps
        CellKind::SramCell => CellParams {
            transistors: 6,
            area_um2: 0.525, // 65 nm 6T bitcell
            energy_per_toggle_fj: 1.32,
            // Cell-internal share of a write access (Fig 15 breakdown).
            energy_per_access_fj: 26_100.0,
            leakage_nw: 0.02,
            delay_ps: 120.0,
        },
        CellKind::Mux2 => CellParams::logic(6, 5.0, 2.64, 0.08, 40.0),
        CellKind::HalfAdder => CellParams::logic(14, 7.6, 4.69, 0.15, 70.0),
        CellKind::FullAdder => CellParams::logic(28, 11.4, 7.61, 0.28, 95.0),
        CellKind::Inv => CellParams::logic(2, 1.0, 1.03, 0.03, 15.0),
        CellKind::Buf => CellParams::logic(4, 1.6, 1.61, 0.05, 28.0),
        CellKind::Nand2 => CellParams::logic(4, 1.6, 1.46, 0.05, 20.0),
        CellKind::Nor2 => CellParams::logic(4, 1.6, 1.46, 0.05, 22.0),
        CellKind::And2 => CellParams::logic(6, 2.2, 2.05, 0.07, 32.0),
        CellKind::Or2 => CellParams::logic(6, 2.2, 2.05, 0.07, 34.0),
        CellKind::Xor2 => CellParams::logic(8, 3.0, 3.22, 0.09, 36.0),
        CellKind::Xnor2 => CellParams::logic(8, 3.0, 3.22, 0.09, 36.0),
        // ---- 8×8 array periphery; per-access energies sum (with the cell
        // write share above) to the paper's 173.8 pJ/bit/access. Areas are
        // calibrated so the routed array totals ≈2502 µm². ----
        CellKind::BitlineConditioner => CellParams {
            transistors: 6,
            area_um2: 60.0,
            energy_per_toggle_fj: 0.0,
            energy_per_access_fj: 89_300.0,
            leakage_nw: 0.4,
            delay_ps: 80.0,
        },
        CellKind::SenseAmp => CellParams {
            transistors: 10,
            area_um2: 80.0,
            energy_per_toggle_fj: 0.0,
            energy_per_access_fj: 22_400.0,
            leakage_nw: 0.6,
            delay_ps: 140.0,
        },
        CellKind::ColumnController => CellParams {
            transistors: 16,
            area_um2: 75.0,
            energy_per_toggle_fj: 0.0,
            energy_per_access_fj: 10_600.0,
            leakage_nw: 0.5,
            delay_ps: 60.0,
        },
        CellKind::RowDecoder => CellParams {
            transistors: 72,
            area_um2: 200.0,
            energy_per_toggle_fj: 0.0,
            energy_per_access_fj: 15_600.0,
            leakage_nw: 1.2,
            delay_ps: 110.0,
        },
        CellKind::ColumnDecoder => CellParams {
            transistors: 72,
            area_um2: 158.3,
            energy_per_toggle_fj: 0.0,
            energy_per_access_fj: 9_800.0,
            leakage_nw: 1.2,
            delay_ps: 110.0,
        },
    })
}

/// Paper constant: measured array write energy, J per bit per access.
pub const PAPER_WRITE_ENERGY_PJ_PER_BIT: f64 = 173.8;
/// Paper constant: mux-based multiplier energy share, fJ per operation.
pub const PAPER_MULT_ENERGY_FJ: f64 = 47.96;
/// Paper constant: LUNA unit routed area, µm².
pub const PAPER_UNIT_AREA_UM2: f64 = 287.0;
/// Paper constant: 8×8 array + 4 LUNA units total routed area, µm².
pub const PAPER_TOTAL_AREA_UM2: f64 = 3650.0;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unit_area_calibration_hits_287() {
        let lib = tsmc65_library();
        // Optimized D&C 4-bit unit: 10 SRAM + 36 MUX2 + 3 HA + 3 FA (Fig 3).
        let placed = lib.cell_area(CellKind::SramCell, 10)
            + lib.cell_area(CellKind::Mux2, 36)
            + lib.cell_area(CellKind::HalfAdder, 3)
            + lib.cell_area(CellKind::FullAdder, 3);
        let routed = lib.routed_area(placed);
        assert!(
            (routed - PAPER_UNIT_AREA_UM2).abs() < 0.5,
            "routed unit area {routed} vs paper 287"
        );
    }

    #[test]
    fn write_energy_breakdown_sums_to_173_8_pj() {
        let lib = tsmc65_library();
        let total_fj = [
            CellKind::SramCell,
            CellKind::BitlineConditioner,
            CellKind::SenseAmp,
            CellKind::ColumnController,
            CellKind::RowDecoder,
            CellKind::ColumnDecoder,
        ]
        .iter()
        .map(|&k| lib.params(k).energy_per_access_fj)
        .sum::<f64>();
        assert!(((total_fj / 1000.0) - PAPER_WRITE_ENERGY_PJ_PER_BIT).abs() < 1e-9);
    }
}
