//! Component-count / cost reporting shared by every multiplier config and
//! the SRAM array model. This is what regenerates the paper's Tables I/II
//! and the Fig 16/18 area breakdowns.

use super::{CellKind, CellLibrary};
use std::fmt;
use std::ops::{Add, AddAssign};

/// Counts of every cell kind in a design, with derived cost queries.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct CostReport {
    counts: Vec<u64>,
}

impl CostReport {
    /// Empty report.
    pub fn new() -> Self {
        CostReport { counts: vec![0; CellKind::ALL.len()] }
    }

    /// Add `n` instances of `kind`.
    pub fn tally(&mut self, kind: CellKind, n: u64) {
        self.counts[kind.index()] += n;
    }

    /// Build from `(kind, count)` pairs.
    pub fn from_pairs(pairs: &[(CellKind, u64)]) -> Self {
        let mut r = Self::new();
        for &(k, n) in pairs {
            r.tally(k, n);
        }
        r
    }

    /// Count of one kind.
    pub fn count(&self, kind: CellKind) -> u64 {
        self.counts[kind.index()]
    }

    /// Total number of cell instances.
    pub fn total_cells(&self) -> u64 {
        self.counts.iter().sum()
    }

    /// Total transistor count under `lib` (the Fig 16 metric).
    pub fn transistors(&self, lib: &CellLibrary) -> u64 {
        CellKind::ALL
            .iter()
            .map(|&k| self.count(k) * lib.params(k).transistors as u64)
            .sum()
    }

    /// Placed area (µm², no routing factor).
    pub fn placed_area_um2(&self, lib: &CellLibrary) -> f64 {
        CellKind::ALL.iter().map(|&k| lib.cell_area(k, self.count(k))).sum()
    }

    /// Routed area (µm², with the library's routing-overhead factor).
    pub fn routed_area_um2(&self, lib: &CellLibrary) -> f64 {
        lib.routed_area(self.placed_area_um2(lib))
    }

    /// Static leakage power (nW).
    pub fn leakage_nw(&self, lib: &CellLibrary) -> f64 {
        CellKind::ALL
            .iter()
            .map(|&k| self.count(k) as f64 * lib.params(k).leakage_nw)
            .sum()
    }

    /// Per-kind breakdown of placed area — the stacked segments of Fig 16.
    pub fn area_breakdown(&self, lib: &CellLibrary) -> Vec<(CellKind, f64)> {
        CellKind::ALL
            .iter()
            .filter(|&&k| self.count(k) > 0)
            .map(|&k| (k, lib.cell_area(k, self.count(k))))
            .collect()
    }

    /// Non-zero `(kind, count)` pairs in stable order.
    pub fn nonzero(&self) -> Vec<(CellKind, u64)> {
        CellKind::ALL
            .iter()
            .filter(|&&k| self.count(k) > 0)
            .map(|&k| (k, self.count(k)))
            .collect()
    }
}

impl Add for CostReport {
    type Output = CostReport;
    fn add(mut self, rhs: CostReport) -> CostReport {
        self += rhs;
        self
    }
}

impl AddAssign for CostReport {
    fn add_assign(&mut self, rhs: CostReport) {
        for (a, b) in self.counts.iter_mut().zip(rhs.counts.iter()) {
            *a += b;
        }
    }
}

impl fmt::Display for CostReport {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let parts: Vec<String> =
            self.nonzero().iter().map(|(k, n)| format!("{}×{}", n, k.name())).collect();
        write!(f, "{}", parts.join(" + "))
    }
}

#[cfg(test)]
mod tests {
    use super::super::tsmc65_library;
    use super::*;

    #[test]
    fn add_and_query() {
        let mut r = CostReport::new();
        r.tally(CellKind::SramCell, 10);
        r.tally(CellKind::Mux2, 36);
        assert_eq!(r.count(CellKind::SramCell), 10);
        assert_eq!(r.total_cells(), 46);
    }

    #[test]
    fn transistor_count_matches_by_hand() {
        let lib = tsmc65_library();
        let r = CostReport::from_pairs(&[(CellKind::SramCell, 2), (CellKind::FullAdder, 1)]);
        assert_eq!(r.transistors(&lib), 2 * 6 + 28);
    }

    #[test]
    fn sum_of_reports() {
        let a = CostReport::from_pairs(&[(CellKind::Mux2, 3)]);
        let b = CostReport::from_pairs(&[(CellKind::Mux2, 4), (CellKind::Inv, 1)]);
        let s = a + b;
        assert_eq!(s.count(CellKind::Mux2), 7);
        assert_eq!(s.count(CellKind::Inv), 1);
    }

    #[test]
    fn display_nonzero_only() {
        let r = CostReport::from_pairs(&[(CellKind::Mux2, 3)]);
        assert_eq!(format!("{r}"), "3×MUX2");
    }
}
