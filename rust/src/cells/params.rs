//! Per-cell physical parameters and the parametric cell library.

use super::CellKind;

/// Physical parameters of one cell kind.
///
/// * `transistors` — static-CMOS transistor count (what Fig 16 of the paper
///   counts via the "TSMC 65 nm digital library as a reference").
/// * `area_um2` — placed cell area in µm² (before routing overhead).
/// * `energy_per_toggle_fj` — dynamic energy per *output toggle* in fJ
///   (CV² with a per-cell effective capacitance at VDD = 1.2 V).
/// * `energy_per_access_fj` — for periphery cells that are exercised once
///   per array access rather than per logic toggle (sense amps, bitline
///   conditioning, decoders). Zero for plain logic.
/// * `leakage_nw` — static leakage power in nW at 27 °C.
/// * `delay_ps` — characteristic propagation delay in ps (used by the
///   event-driven simulator for Fig 14 transients).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CellParams {
    pub transistors: u32,
    pub area_um2: f64,
    pub energy_per_toggle_fj: f64,
    pub energy_per_access_fj: f64,
    pub leakage_nw: f64,
    pub delay_ps: f64,
}

impl CellParams {
    /// Convenience constructor for pure-logic cells (no per-access energy).
    pub const fn logic(
        transistors: u32,
        area_um2: f64,
        energy_per_toggle_fj: f64,
        leakage_nw: f64,
        delay_ps: f64,
    ) -> Self {
        CellParams {
            transistors,
            area_um2,
            energy_per_toggle_fj,
            energy_per_access_fj: 0.0,
            leakage_nw,
            delay_ps,
        }
    }
}

/// A complete cell library: parameters for every [`CellKind`] plus global
/// calibration knobs.
#[derive(Debug, Clone)]
pub struct CellLibrary {
    /// Human-readable name (e.g. `"tsmc65-like"`).
    pub name: String,
    /// Supply voltage in volts (65 nm nominal: 1.2 V).
    pub vdd: f64,
    /// Multiplicative factor applied on top of summed cell areas to account
    /// for routing / whitespace. Calibrated so the optimized-D&C LUNA unit
    /// lands on the paper's 287 µm².
    pub routing_overhead: f64,
    /// Parameters per cell kind, indexed by [`CellKind::index`].
    params: Vec<CellParams>,
}

impl CellLibrary {
    /// Build a library from a parameter function.
    pub fn from_fn(
        name: impl Into<String>,
        vdd: f64,
        routing_overhead: f64,
        f: impl Fn(CellKind) -> CellParams,
    ) -> Self {
        CellLibrary {
            name: name.into(),
            vdd,
            routing_overhead,
            params: CellKind::ALL.iter().map(|&k| f(k)).collect(),
        }
    }

    /// Parameters for a cell kind.
    pub fn params(&self, kind: CellKind) -> CellParams {
        self.params[kind.index()]
    }

    /// Placed area of `count` instances of `kind`, µm² (no routing factor).
    pub fn cell_area(&self, kind: CellKind, count: u64) -> f64 {
        self.params(kind).area_um2 * count as f64
    }

    /// Apply the routing-overhead factor to a raw placed area.
    pub fn routed_area(&self, placed_um2: f64) -> f64 {
        placed_um2 * self.routing_overhead
    }
}

#[cfg(test)]
mod tests {
    use super::super::tsmc65_library;
    use super::*;

    #[test]
    fn params_cover_all_kinds() {
        let lib = tsmc65_library();
        for &k in &CellKind::ALL {
            let p = lib.params(k);
            assert!(p.transistors > 0, "{k:?} has transistors");
            assert!(p.area_um2 > 0.0, "{k:?} has area");
        }
    }

    #[test]
    fn routed_area_scales() {
        let lib = tsmc65_library();
        assert!(lib.routed_area(100.0) > 100.0);
    }
}
