//! Standard-cell and memory-cell models (65 nm-like).
//!
//! The paper evaluates LUNA-CiM on TSMC 65 nm silicon. We do not have that
//! PDK; instead this module provides a **parametric cell library** whose
//! per-cell transistor counts are textbook static-CMOS values and whose
//! area/energy/delay constants are calibrated so the paper's *aggregate*
//! claims hold (287 µm² per LUNA unit, 3650 µm² for the 8×8 array + 4 units,
//! 173.8 pJ/bit/access array write energy, 47.96 fJ per multiply ≈ 0.0276 %).
//! All reproduced results are ratios over this common library, which is the
//! substitution DESIGN.md §2 documents.

mod kinds;
mod params;
mod report;
pub mod tsmc65;

pub use kinds::CellKind;
pub use params::{CellLibrary, CellParams};
pub use report::CostReport;
pub use tsmc65::tsmc65_library;
