//! Enumeration of every cell kind used by the LUNA-CiM netlists and the
//! SRAM-array periphery model.


/// A physical cell in the design. Primitive logic gates (`Inv` … `Mux2`)
/// are what netlists are built from; `HalfAdder`/`FullAdder` are *composite*
/// cells (the paper counts them as units, matching standard-cell libraries
/// that provide HA/FA macros); the remaining kinds are SRAM-array periphery
/// components used by the energy/area model of Figs 15/18.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum CellKind {
    /// 6T SRAM bit cell (storage for LUT entries and array data).
    SramCell,
    /// 2:1 one-bit multiplexer (transmission-gate style + select inverter).
    Mux2,
    /// Half adder macro (XOR + AND).
    HalfAdder,
    /// Full adder macro (mirror adder).
    FullAdder,
    /// Static CMOS inverter.
    Inv,
    /// Buffer (two inverters).
    Buf,
    /// 2-input NAND.
    Nand2,
    /// 2-input NOR.
    Nor2,
    /// 2-input AND (NAND + INV).
    And2,
    /// 2-input OR (NOR + INV).
    Or2,
    /// 2-input XOR.
    Xor2,
    /// 2-input XNOR.
    Xnor2,
    // ---- SRAM array periphery (Figs 15, 17, 18) ----
    /// Bit-line conditioning unit (precharge + equalise), one per column.
    BitlineConditioner,
    /// Differential sense amplifier, one per column.
    SenseAmp,
    /// Column controller (write driver + column mux), one per column.
    ColumnController,
    /// Row decoder (shared, per array).
    RowDecoder,
    /// Column decoder (shared, per array).
    ColumnDecoder,
}

impl CellKind {
    /// Every kind, in a stable order (used for report tables).
    pub const ALL: [CellKind; 17] = [
        CellKind::SramCell,
        CellKind::Mux2,
        CellKind::HalfAdder,
        CellKind::FullAdder,
        CellKind::Inv,
        CellKind::Buf,
        CellKind::Nand2,
        CellKind::Nor2,
        CellKind::And2,
        CellKind::Or2,
        CellKind::Xor2,
        CellKind::Xnor2,
        CellKind::BitlineConditioner,
        CellKind::SenseAmp,
        CellKind::ColumnController,
        CellKind::RowDecoder,
        CellKind::ColumnDecoder,
    ];

    /// Stable index into [`CellKind::ALL`] (used by count vectors).
    pub fn index(self) -> usize {
        Self::ALL.iter().position(|k| *k == self).expect("kind in ALL")
    }

    /// Short display name, matching the labels the paper uses in its
    /// component tables.
    pub fn name(self) -> &'static str {
        match self {
            CellKind::SramCell => "SRAM",
            CellKind::Mux2 => "MUX2",
            CellKind::HalfAdder => "HA",
            CellKind::FullAdder => "FA",
            CellKind::Inv => "INV",
            CellKind::Buf => "BUF",
            CellKind::Nand2 => "NAND2",
            CellKind::Nor2 => "NOR2",
            CellKind::And2 => "AND2",
            CellKind::Or2 => "OR2",
            CellKind::Xor2 => "XOR2",
            CellKind::Xnor2 => "XNOR2",
            CellKind::BitlineConditioner => "BL-COND",
            CellKind::SenseAmp => "SENSE-AMP",
            CellKind::ColumnController => "COL-CTRL",
            CellKind::RowDecoder => "ROW-DEC",
            CellKind::ColumnDecoder => "COL-DEC",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_indices_roundtrip() {
        for (i, k) in CellKind::ALL.iter().enumerate() {
            assert_eq!(k.index(), i);
        }
    }

    #[test]
    fn names_unique() {
        let mut names: Vec<_> = CellKind::ALL.iter().map(|k| k.name()).collect();
        names.sort();
        names.dedup();
        assert_eq!(names.len(), CellKind::ALL.len());
    }
}
