//! Figure regenerators (Figs 1–18).

use super::text_table;
use crate::analysis::{error_map, hamming, mae, probability};
use crate::cells::{tsmc65_library, CellKind};
use crate::logic::{to_bits, BusTrace, EventSim};
use crate::luna::{LunaBank, LunaUnit};
use crate::multiplier::MultiplierKind;
use crate::sram::SramArray;
use std::fmt::Write as _;

/// Figs 1–4, 9, 10 — structure inventories of each configuration.
pub fn fig_structure(id: u32) -> String {
    let (kind, caption): (MultiplierKind, &str) = match id {
        1 => (MultiplierKind::Traditional, "Fig 1 — conventional 4b LUT multiplier"),
        2 => (MultiplierKind::Dnc, "Fig 2 — D&C LUT multiplier"),
        3 => (MultiplierKind::DncOpt, "Fig 3 — optimized D&C LUT multiplier"),
        4 => (MultiplierKind::Approx, "Fig 4/9 — ApproxD&C (final form, Z_LSB = 0)"),
        9 => (MultiplierKind::Approx, "Fig 9 — ApproxD&C final structure"),
        10 => (MultiplierKind::Approx2, "Fig 10 — ApproxD&C 2 (Z_LSB = W)"),
        _ => panic!("fig_structure handles figs 1-4, 9, 10"),
    };
    let lib = tsmc65_library();
    let netlist = kind.netlist().expect("hardware config");
    let cost = netlist.cost_report();
    let mut out = format!("{caption}\n  components: {cost}\n");
    let _ = writeln!(out, "  transistors: {}", cost.transistors(&lib));
    let _ = writeln!(
        out,
        "  placed area: {:.1} um^2, routed: {:.1} um^2",
        cost.placed_area_um2(&lib),
        cost.routed_area_um2(&lib)
    );
    out
}

/// Fig 5 — probability stem chart of the (4b×2b) LSB-side product.
pub fn fig5() -> String {
    let pmf = probability::lsb_product_pmf();
    let mut out = String::from(
        "Fig 5 — P(product) of the 4b x 2b LSB-side multiplication\n  value  prob    stem\n",
    );
    for (v, &p) in pmf.iter().enumerate() {
        if p > 0.0 {
            let stars = "*".repeat((p * 200.0).round() as usize);
            let _ = writeln!(out, "  {v:>5}  {p:.4}  {stars}");
        }
    }
    let _ = writeln!(
        out,
        "  P(0) = {:.4}  (paper: 0.296); impossible values: {:?}",
        probability::probability_of_zero(),
        probability::impossible_values()
    );
    out
}

/// Fig 5 as CSV (`value,probability`).
pub fn fig5_csv() -> String {
    let mut out = String::from("value,probability\n");
    for (v, p) in probability::lsb_product_pmf().iter().enumerate() {
        let _ = writeln!(out, "{v},{p}");
    }
    out
}

/// Fig 6 — mean per-bit Hamming distance per fixed-Z_LSB candidate.
pub fn fig6() -> String {
    let d = hamming::mean_hamming_per_candidate();
    let (best, best_d) = hamming::best_candidate();
    let mut out =
        String::from("Fig 6 — mean Hamming distance per approximated Z_LSB candidate\n");
    for (c, &v) in d.iter().enumerate() {
        if c % 8 == 0 {
            let _ = write!(out, "  {c:>2}:");
        }
        let _ = write!(out, " {v:.3}");
        if c % 8 == 7 {
            out.push('\n');
        }
    }
    let _ = writeln!(out, "  minimum {best_d:.3} at candidate {best} (paper: 0.275 at 0)");
    out
}

/// Fig 6 as CSV.
pub fn fig6_csv() -> String {
    let mut out = String::from("candidate,mean_hamming\n");
    for (c, v) in hamming::mean_hamming_per_candidate().iter().enumerate() {
        let _ = writeln!(out, "{c},{v}");
    }
    out
}

/// Figs 7 / 11 — error heatmap of an approximate config vs exact D&C.
pub fn fig_heatmap(id: u32) -> String {
    let (kind, caption) = match id {
        7 => (MultiplierKind::Approx, "Fig 7 — |D&C − ApproxD&C| heatmap"),
        11 => (MultiplierKind::Approx2, "Fig 11 — D&C − ApproxD&C2 heatmap"),
        _ => panic!("fig_heatmap handles figs 7 and 11"),
    };
    let m = error_map::error_map(kind);
    let mut out = format!("{caption} (rows = Weight 0..15, cols = Data 0..15)\n");
    for w in 0..16 {
        let _ = write!(out, "  W={w:>2} |");
        for y in 0..16 {
            let _ = write!(out, "{:>4}", m.err[w][y]);
        }
        out.push('\n');
    }
    let (lo, hi) = m.range();
    let _ = writeln!(
        out,
        "  range [{lo}, {hi}], mean signed error {:.3}, MAE {:.3}",
        m.mean_error(),
        m.mean_abs_error()
    );
    out
}

/// Figs 8 / 12 — error histograms.
pub fn fig_histogram(id: u32) -> String {
    let (kind, caption) = match id {
        8 => (MultiplierKind::Approx, "Fig 8 — ApproxD&C error histogram"),
        12 => (MultiplierKind::Approx2, "Fig 12 — ApproxD&C2 error histogram"),
        _ => panic!("fig_histogram handles figs 8 and 12"),
    };
    let m = error_map::error_map(kind);
    let mut out = format!("{caption}\n  error  count  bar\n");
    for (e, c) in m.histogram() {
        let _ = writeln!(out, "  {e:>5}  {c:>5}  {}", "#".repeat(c as usize));
    }
    out
}

/// Fig 13 — MAE per multiplier configuration (100 iterations, like the
/// paper's MATLAB study).
pub fn fig13(iters: usize, seed: u64) -> String {
    let results = mae::fig13_study(iters, seed);
    let rows: Vec<Vec<String>> = results
        .iter()
        .map(|r| {
            vec![
                r.kind.name().to_string(),
                format!("{:.4}", r.element_mae),
                format!("{:.4}", r.network_mae),
            ]
        })
        .collect();
    let mut out = format!("Fig 13 — Mean Absolute Error vs IDEAL ({iters} iterations)\n");
    out.push_str(&text_table(&["configuration", "element MAE", "network MAE"], &rows));
    out
}

/// Fig 14 — transient simulation of the mux-based multiplier:
/// W = 0110 fixed, Y ∈ {1010, 1011, 0011, 1100} applied sequentially.
pub fn fig14() -> String {
    let kind = MultiplierKind::DncOpt;
    let netlist = kind.netlist().unwrap();
    let mut sim = EventSim::new(&netlist);
    sim.watch_bus("Y");
    sim.watch_bus("OUT");
    sim.program(&kind.program_image(0b0110).unwrap());
    let ys = [0b1010u64, 0b1011, 0b0011, 0b1100];
    let vectors: Vec<Vec<bool>> = ys.iter().map(|&y| to_bits(y, 4)).collect();
    let waves = sim.run_schedule(&vectors, 2_000); // 2 ns per applied vector
    let trace = BusTrace::new(waves);
    let mut out = String::from(
        "Fig 14 — transient: W<3:0> = 0110, Y applied as 1010, 1011, 0011, 1100\n",
    );
    out.push_str(&trace.render());
    let _ = writeln!(
        out,
        "expected OUT: 60, 66, 18, 72; settle stats: {} transitions, {} events",
        sim.stats().transitions,
        sim.stats().events
    );
    out
}

/// Fig 14 as CSV.
pub fn fig14_csv() -> String {
    let kind = MultiplierKind::DncOpt;
    let netlist = kind.netlist().unwrap();
    let mut sim = EventSim::new(&netlist);
    sim.watch_bus("Y");
    sim.watch_bus("OUT");
    sim.program(&kind.program_image(0b0110).unwrap());
    let vectors: Vec<Vec<bool>> =
        [0b1010u64, 0b1011, 0b0011, 0b1100].iter().map(|&y| to_bits(y, 4)).collect();
    BusTrace::new(sim.run_schedule(&vectors, 2_000)).to_csv()
}

/// Fig 15 — energy of the main components in the 8×8 array, plus the
/// multiplier's measured share (§IV.B: 173.8 pJ/bit vs 47.96 fJ ≈ 0.0276 %).
pub fn fig15() -> String {
    let lib = tsmc65_library();
    // Write sweep: program the paper's stimulus through the write path.
    let mut array = SramArray::paper_8x8();
    array.write_row(&lib, 0, 0b0110); // W
    for (i, y) in [0b1010u64, 0b1011, 0b0011, 0b1100].iter().enumerate() {
        array.write_row(&lib, 1 + i, *y);
    }
    let per_bit_pj = array.ledger().total_fj() / array.ledger().accesses() as f64 / 1000.0;

    // Multiplier energy measured from gate-level switching activity.
    let mut unit = LunaUnit::new(MultiplierKind::DncOpt);
    unit.program(&lib, 0b0110);
    for _ in 0..64 {
        for y in [0b1010u8, 0b1011, 0b0011, 0b1100] {
            let _ = unit.multiply(&lib, y);
        }
    }
    let mult_fj = unit.avg_multiply_energy_fj();
    let share = mult_fj / (per_bit_pj * 1000.0);

    let rows: Vec<Vec<String>> = array
        .ledger()
        .breakdown()
        .rows()
        .iter()
        .map(|(k, fj, frac)| {
            vec![
                k.name().to_string(),
                format!("{:.1}", fj / array.ledger().accesses() as f64 / 1000.0),
                format!("{:.1}%", frac * 100.0),
            ]
        })
        .collect();
    let mut out = String::from("Fig 15 — energy of main components, 8x8 SRAM array (per bit-access)\n");
    out.push_str(&text_table(&["component", "pJ/bit/access", "share"], &rows));
    let _ = writeln!(out, "array write energy: {per_bit_pj:.1} pJ/bit/access (paper: 173.8)");
    let _ = writeln!(
        out,
        "mux-based multiplier: {mult_fj:.2} fJ/op = {:.4}% of a bit access (paper: 47.96 fJ, 0.0276%)",
        share * 100.0
    );
    out
}

/// Fig 16 — area comparison across configurations, stacked by component.
pub fn fig16() -> String {
    let lib = tsmc65_library();
    let mut rows = Vec::new();
    for kind in MultiplierKind::PAPER_CONFIGS {
        let cost = kind.netlist().unwrap().cost_report();
        let breakdown = cost.area_breakdown(&lib);
        let seg = |k: CellKind| {
            breakdown.iter().find(|(kk, _)| *kk == k).map(|(_, a)| *a).unwrap_or(0.0)
        };
        rows.push(vec![
            kind.name().to_string(),
            format!("{}", cost.transistors(&lib)),
            format!("{:.1}", seg(CellKind::SramCell)),
            format!("{:.1}", seg(CellKind::Mux2)),
            format!("{:.1}", seg(CellKind::HalfAdder) + seg(CellKind::FullAdder)),
            format!("{:.1}", cost.routed_area_um2(&lib)),
        ]);
    }
    let mut out = String::from("Fig 16 — area by configuration (4b W x 4b Y), stacked segments\n");
    out.push_str(&text_table(
        &["configuration", "transistors", "SRAM um2", "MUX um2", "adders um2", "routed um2"],
        &rows,
    ));
    let trad = MultiplierKind::Traditional.netlist().unwrap().cost_report().routed_area_um2(&lib);
    let dnc = MultiplierKind::Dnc.netlist().unwrap().cost_report().routed_area_um2(&lib);
    let _ = writeln!(
        out,
        "traditional / D&C area ratio: {:.2}x (paper: ~3.7x less area for D&C)",
        trad / dnc
    );
    out
}

/// Fig 17 — the 8×8 array with four LUNA units: structure inventory.
pub fn fig17() -> String {
    let bank = LunaBank::paper_config(MultiplierKind::DncOpt);
    let mut out = String::from(
        "Fig 17 — 8x8 SRAM array with four LUNA-CiM units\n\
         each unit reads Y from its upper row, multiplies by the programmed W,\n\
         and writes the 8b product to its lower row.\n",
    );
    let _ = writeln!(out, "  array: {}", bank.array.cost());
    let _ = writeln!(out, "  per unit: {}", bank.units[0].cost());
    let _ = writeln!(out, "  total: {}", bank.cost());
    out
}

/// Fig 18 — area pie chart of the array + 4 units.
pub fn fig18() -> String {
    let lib = tsmc65_library();
    let bank = LunaBank::paper_config(MultiplierKind::DncOpt);
    let rep = bank.area_report(&lib);
    let mut out = String::from("Fig 18 — area distribution, 8x8 array + 4 LUNA units\n");
    let _ = writeln!(out, "  SRAM array : {:>8.1} um2 ({:.1}%)", rep.array_um2, 100.0 * (1.0 - rep.overhead_fraction));
    let _ = writeln!(
        out,
        "  LUNA units : {:>8.1} um2 ({:.1}%)  [4 x {:.1} um2; paper: 4 x 287 um2 = 32%]",
        rep.units_total_um2,
        100.0 * rep.overhead_fraction,
        rep.unit_um2
    );
    let _ = writeln!(out, "  total      : {:>8.1} um2 (paper: 3650 um2)", rep.total_um2);
    out
}

/// Dispatch by figure id (the CLI's `figures --id N`).
pub fn figure(id: u32) -> String {
    match id {
        1 | 2 | 3 | 9 | 10 => fig_structure(id),
        4 => fig_structure(4),
        5 => fig5(),
        6 => fig6(),
        7 | 11 => fig_heatmap(id),
        8 | 12 => fig_histogram(id),
        13 => fig13(100, 2024),
        14 => fig14(),
        15 => fig15(),
        16 => fig16(),
        17 => fig17(),
        18 => fig18(),
        _ => format!("no figure {id} in the paper"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_figure_renders() {
        for id in [1u32, 2, 3, 4, 5, 6, 7, 8, 9, 10, 11, 12, 14, 15, 16, 17, 18] {
            let text = figure(id);
            assert!(!text.is_empty(), "fig {id}");
        }
    }

    #[test]
    fn fig14_contains_expected_products() {
        let text = fig14();
        for v in ["60", "66", "18", "72"] {
            assert!(text.contains(v), "missing {v} in:\n{text}");
        }
    }

    #[test]
    fn fig15_hits_paper_constants() {
        let text = fig15();
        assert!(text.contains("173.8"));
    }

    #[test]
    fn fig18_reports_32_percent() {
        let text = fig18();
        assert!(text.contains("32"), "{text}");
    }

    #[test]
    fn fig5_lists_impossible_values() {
        assert!(fig5().contains("P(0)"));
        assert!(fig5_csv().lines().count() == 65);
    }
}
