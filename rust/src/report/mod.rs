//! Regenerators for every table and figure in the paper's evaluation.
//!
//! Each function returns the table/series as printable text (and CSV where
//! a figure is a data series); the CLI (`repro tables|figures`) and the
//! criterion benches print these, and EXPERIMENTS.md records paper-vs-
//! measured values.

mod figures;
mod tables;

pub use figures::*;
pub use tables::*;

/// Render an aligned text table: header + rows.
pub(crate) fn text_table(header: &[&str], rows: &[Vec<String>]) -> String {
    let mut widths: Vec<usize> = header.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            widths[i] = widths[i].max(cell.len());
        }
    }
    let fmt_row = |cells: &[String]| {
        cells
            .iter()
            .enumerate()
            .map(|(i, c)| format!("{:>w$}", c, w = widths[i]))
            .collect::<Vec<_>>()
            .join("  ")
    };
    let mut out = String::new();
    out.push_str(&fmt_row(&header.iter().map(|s| s.to_string()).collect::<Vec<_>>()));
    out.push('\n');
    out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
    out.push('\n');
    for row in rows {
        out.push_str(&fmt_row(row));
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    #[test]
    fn text_table_aligns() {
        let t = super::text_table(
            &["a", "bbb"],
            &[vec!["1".into(), "2".into()], vec!["333".into(), "4".into()]],
        );
        assert!(t.contains("333"));
        assert!(t.lines().count() == 4);
    }
}
