//! Tables I and II.

use super::text_table;
use crate::cells::CellKind;
use crate::multiplier::{generic, traditional};

/// Table I — SRAM cells and 2:1 muxes for 3b–8b traditional LUT multiply.
pub fn table1() -> String {
    let rows: Vec<Vec<String>> = (3..=8u32)
        .map(|k| {
            vec![
                format!("{k}b"),
                traditional::sram_bits(k).to_string(),
                traditional::mux_count(k).to_string(),
            ]
        })
        .collect();
    let mut out = String::from(
        "Table I — traditional LUT-based multiplication cost (paper Table I)\n",
    );
    out.push_str(&text_table(
        &["Multiplier Bit Resolution", "Number of SRAMs", "Number of 2:1 1b MUXes"],
        &rows,
    ));
    out
}

/// Table I raw rows: `(k, srams, muxes)`.
pub fn table1_rows() -> Vec<(u32, u64, u64)> {
    (3..=8u32).map(|k| (k, traditional::sram_bits(k), traditional::mux_count(k))).collect()
}

/// Table II — traditional vs optimized D&C for 4b, 8b, 16b. The optimized
/// column is counted **from the constructed netlists**, not formulas.
pub fn table2() -> String {
    let rows: Vec<Vec<String>> = [4u32, 8, 16]
        .iter()
        .map(|&n| {
            let netlist = generic::netlist(n);
            let r = netlist.cost_report();
            vec![
                format!("{n}b"),
                traditional::sram_bits(n).to_string(),
                traditional::mux_count(n).to_string(),
                r.count(CellKind::SramCell).to_string(),
                r.count(CellKind::Mux2).to_string(),
                r.count(CellKind::HalfAdder).to_string(),
                r.count(CellKind::FullAdder).to_string(),
            ]
        })
        .collect();
    let mut out = String::from(
        "Table II — traditional vs optimized D&C LUT multiplication (paper Table II)\n",
    );
    out.push_str(&text_table(
        &["Resolution", "Trad SRAMs", "Trad MUXes", "D&C SRAMs", "D&C MUXes", "HAs", "FAs"],
        &rows,
    ));
    out
}

/// Table II raw rows: `(n, trad_sram, trad_mux, opt)`.
pub fn table2_rows() -> Vec<(u32, u64, u64, generic::DncCounts)> {
    [4u32, 8, 16]
        .iter()
        .map(|&n| (n, traditional::sram_bits(n), traditional::mux_count(n), generic::counts(n)))
        .collect()
}

#[cfg(test)]
mod tests {
    #[test]
    fn table1_matches_paper_rows() {
        let rows = super::table1_rows();
        assert_eq!(rows[0], (3, 48, 42));
        assert_eq!(rows[5], (8, 4096, 4080));
        let text = super::table1();
        assert!(text.contains("4096"));
    }

    #[test]
    fn table2_matches_paper_rows() {
        let rows = super::table2_rows();
        let (n, ts, tm, opt) = &rows[2];
        assert_eq!(*n, 16);
        assert_eq!(*ts, 2_097_152);
        assert_eq!(*tm, 2_097_120);
        assert_eq!(opt.srams, 136);
        assert_eq!(opt.muxes, 432);
        assert_eq!(opt.has, 31);
        assert_eq!(opt.fas, 105);
        assert!(super::table2().contains("2097152"));
    }
}
