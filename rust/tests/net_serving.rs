//! Loopback integration tests of the wire-protocol serving subsystem
//! (`rust/src/net/`): TCP front-end + coordinator over synthesized
//! artifacts — no `make artifacts`, no HLO files, no external network.
//!
//! Pins the acceptance bars of the net subsystem:
//! * wire-served responses are **bit-identical** with direct in-process
//!   `submit` for every backend exercised here (`native`, `calibrated`);
//! * the rejection path returns a parseable 429-style retry hint, both
//!   on the wire and (as a downcastable [`Backpressure`]) in-process;
//! * malformed/truncated/mis-versioned frames close that connection
//!   without poisoning the coordinator or other connections;
//! * graceful shutdown drains in-flight requests before closing;
//! * the router front tier is transparent and never hangs a request:
//!   killing a backend mid-load resolves every in-flight request with a
//!   retryable frame, quarantines the endpoint, and recovers it when a
//!   health probe succeeds again;
//! * multi-tenant serving is invisible in the replies: model-tagged
//!   requests are bit-identical across shard counts, plan-thread
//!   counts, cache evictions and the router, and a hot swap
//!   (`LoadModel` + `RetireModel` under live load) drops no connection
//!   and resolves every in-flight request;
//! * observability is wire-true: a routed request's spans from the
//!   router and the backend stitch into one ordered Chrome timeline by
//!   trace id, a `GetStats` scrape equals the in-process snapshot on a
//!   quiesced server (and fans out through the router), and
//!   hand-rolled v0.2 frames still serve unchanged against the v0.3
//!   protocol.

mod common;

use common::synth_artifacts;
use luna_cim::config::{
    BackendKind, Config, DispatchPolicy, RouterConfig, ShardAffinity, TraceConfig,
};
use luna_cim::coordinator::{Backpressure, CoordinatorServer, MetricsSnapshot, ServerHandle};
use luna_cim::engine::ModelEntry;
use luna_cim::multiplier::{MultiplierKind, MultiplierModel};
use luna_cim::net::protocol::{read_frame, write_frame, Frame, ModelId, MAGIC, VERSION};
use luna_cim::net::{loadgen, NetClient, NetServer, RouterServer, Scenario};
use luna_cim::nn::{GemmOptions, QuantMlp};
use luna_cim::util::trace::{merge_trace_dumps, parse_trace_json};
use luna_cim::util::PoolStats;
use std::io::Write as _;
use std::net::TcpStream;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Start a full serving stack (coordinator + TCP front-end) over
/// synthesized artifacts.
fn start_stack(
    tag: &str,
    mlp: &QuantMlp,
    tweak: impl FnOnce(&mut Config),
) -> (CoordinatorServer, ServerHandle, NetServer, Vec<Vec<f32>>) {
    let (store, testset) = synth_artifacts(tag, mlp, 8);
    let mut cfg = Config::default();
    cfg.artifacts_dir = store.root().display().to_string();
    tweak(&mut cfg);
    let (server, handle) = CoordinatorServer::start(cfg.clone()).unwrap();
    let net = NetServer::bind(handle.clone(), "127.0.0.1:0", cfg.net.max_connections).unwrap();
    let pixels = testset.samples.iter().map(|s| s.pixels.clone()).collect();
    (server, handle, net, pixels)
}

/// Poll the admission counter until `accepted` requests have been
/// admitted (bounds the races in shutdown/backpressure tests).
fn wait_accepted(handle: &ServerHandle, accepted: u64) {
    let t0 = Instant::now();
    while handle.metrics().snapshot().accepted < accepted {
        assert!(t0.elapsed() < Duration::from_secs(5), "requests never admitted");
        std::thread::yield_now();
    }
}

#[test]
fn wire_responses_bit_identical_with_direct_submit_native_and_calibrated() {
    for backend in [BackendKind::Native, BackendKind::Calibrated] {
        let mlp = QuantMlp::random_digits(61);
        let (server, handle, net, pixels) = start_stack("net-bitexact", &mlp, |cfg| {
            cfg.backend = backend;
            cfg.multiplier = MultiplierKind::DncOpt;
        });
        let model = MultiplierModel::new(MultiplierKind::DncOpt);
        let mut client = NetClient::connect(net.local_addr()).unwrap();
        let info = client.info().clone();
        assert_eq!(info.in_dim, 64);
        assert_eq!(info.out_dim, 10);
        assert_eq!(info.max_batch, 8);
        assert_eq!(info.backend, backend.slug());
        for px in pixels.iter().take(12) {
            let wire = match client.infer(px).unwrap() {
                Frame::Response { label, logits, cost, latency_us, .. } => {
                    assert!(latency_us > 0);
                    assert!(cost.energy_fj > 0.0, "{backend:?} prices every reply");
                    if backend == BackendKind::Calibrated {
                        assert!(cost.latency_ps > 0);
                        assert!(cost.programs + cost.stationary_hits > 0);
                    }
                    (label as usize, logits)
                }
                other => panic!("expected a response, got {other:?}"),
            };
            let direct = handle.submit(px.clone()).unwrap();
            assert_eq!(wire.1, direct.logits, "wire logits must be bit-identical");
            assert_eq!(wire.0, direct.label);
            // and both equal the functional model exactly
            assert_eq!(wire.1, mlp.forward(px, &model));
        }
        net.shutdown();
        server.shutdown();
    }
}

#[test]
fn rejection_carries_parseable_retry_hint_on_wire_and_in_process() {
    let mlp = QuantMlp::random_digits(67);
    // strict admission: one outstanding request fills the server
    let (server, handle, net, pixels) = start_stack("net-reject", &mlp, |cfg| {
        cfg.batcher.queue_depth = 1;
        cfg.batcher.max_wait_us = 500_000; // flush well after the test's probes
    });
    let client = NetClient::connect(net.local_addr()).unwrap();
    let (mut tx, mut rx, _info) = client.split();
    tx.send(&pixels[0]).unwrap();
    wait_accepted(&handle, 1);

    // in-process submit: typed Backpressure with a usable hint
    let err = handle.submit(pixels[1].clone()).expect_err("server is full");
    let bp = err.downcast_ref::<Backpressure>().expect("typed backpressure error");
    assert!(bp.retry_after_us >= 1, "hint must be actionable");
    assert!(bp.retry_after_us <= 2_000_000, "hint {} out of scale", bp.retry_after_us);
    assert!(err.to_string().contains("retry in"), "{err}");

    // wire submit: 429-style Rejected frame with the same structured hint
    tx.send(&pixels[1]).unwrap();
    let mut got_reject = None;
    let mut got_response = None;
    for _ in 0..2 {
        match rx.recv().unwrap() {
            Frame::Rejected { id, retry_after_us, reason } => {
                got_reject = Some((id, retry_after_us, reason));
            }
            Frame::Response { id, .. } => got_response = Some(id),
            other => panic!("unexpected {other:?}"),
        }
    }
    let (rid, hint, reason) = got_reject.expect("second request is rejected");
    assert_eq!(rid, 1, "the rejected wire id");
    assert!(hint >= 1 && hint <= 2_000_000, "wire hint {hint}");
    assert!(reason.contains("retry in"), "{reason}");
    assert_eq!(got_response, Some(0), "the admitted request still completes");

    let snap = handle.metrics().snapshot();
    assert_eq!(snap.accepted, 1);
    assert_eq!(snap.rejected, 2);
    assert_eq!(snap.retry_hints, 2, "both rejections carried hints");
    assert!(snap.reject_rate() > 0.5);
    net.shutdown();
    server.shutdown();
}

#[test]
fn malformed_frames_close_connection_without_poisoning_coordinator() {
    let mlp = QuantMlp::random_digits(71);
    let (server, handle, net, pixels) = start_stack("net-garbage", &mlp, |cfg| {
        cfg.batcher.max_wait_us = 1_000;
    });

    // 1) pure garbage bytes: bad magic
    let mut s = TcpStream::connect(net.local_addr()).unwrap();
    s.write_all(b"GARBAGE!GARBAGE!").unwrap();
    match read_frame(&mut s).unwrap() {
        Some(Frame::Error { reason, .. }) => assert!(reason.contains("magic"), "{reason}"),
        other => panic!("expected a protocol error, got {other:?}"),
    }
    assert!(read_frame(&mut s).unwrap().is_none(), "server closes after garbage");

    // 2) truncated frame: valid header, missing payload bytes
    let mut s = TcpStream::connect(net.local_addr()).unwrap();
    let mut buf = Vec::new();
    let req = Frame::Request { id: 0, pixels: vec![0.5; 64].into(), model: ModelId::DEFAULT };
    write_frame(&mut buf, &req).unwrap();
    s.write_all(&buf[..buf.len() - 7]).unwrap();
    s.shutdown(std::net::Shutdown::Write).unwrap();
    match read_frame(&mut s).unwrap() {
        Some(Frame::Error { .. }) => {}
        other => panic!("expected a protocol error, got {other:?}"),
    }
    assert!(read_frame(&mut s).unwrap().is_none());

    // 3) wrong protocol *major* version: rejected by name. (A higher
    // minor of the same major is forward-compatible and accepted — see
    // the protocol tests — so the mismatch here flips the major nibble.)
    let mut s = TcpStream::connect(net.local_addr()).unwrap();
    let header = [MAGIC[0], MAGIC[1], VERSION + 0x10, 0x05, 0, 0, 0, 0];
    s.write_all(&header).unwrap();
    match read_frame(&mut s).unwrap() {
        Some(Frame::Error { reason, .. }) => assert!(reason.contains("version"), "{reason}"),
        other => panic!("expected a version error, got {other:?}"),
    }

    // the coordinator is untouched: both a fresh wire client and the
    // in-process path still serve, bit-exact
    let model = MultiplierModel::new(MultiplierKind::DncOpt);
    let mut client = NetClient::connect(net.local_addr()).unwrap();
    match client.infer(&pixels[0]).unwrap() {
        Frame::Response { logits, .. } => assert_eq!(logits, mlp.forward(&pixels[0], &model)),
        other => panic!("unexpected {other:?}"),
    }
    let direct = handle.submit(pixels[1].clone()).unwrap();
    assert_eq!(direct.logits, mlp.forward(&pixels[1], &model));
    let snap = handle.metrics().snapshot();
    assert_eq!(snap.failed_batches, 0, "garbage must never reach a batch");
    net.shutdown();
    server.shutdown();
}

#[test]
fn graceful_shutdown_drains_in_flight_requests() {
    let mlp = QuantMlp::random_digits(73);
    let (server, handle, net, pixels) = start_stack("net-drain", &mlp, |cfg| {
        // partial batch: 3 requests sit in the batcher until the
        // 30 ms deadline flush — genuinely in flight during shutdown
        cfg.batcher.max_wait_us = 30_000;
    });
    let client = NetClient::connect(net.local_addr()).unwrap();
    let (mut tx, mut rx, _info) = client.split();
    for px in pixels.iter().take(3) {
        tx.send(px).unwrap();
    }
    wait_accepted(&handle, 3);
    net.shutdown(); // must block until the in-flight replies are written
    let mut labels = Vec::new();
    for _ in 0..3 {
        match rx.recv().unwrap() {
            Frame::Response { id, label, .. } => labels.push((id, label)),
            other => panic!("in-flight request lost in shutdown: {other:?}"),
        }
    }
    labels.sort_unstable();
    let model = MultiplierModel::new(MultiplierKind::DncOpt);
    for (i, (id, label)) in labels.into_iter().enumerate() {
        assert_eq!(id, i as u64);
        assert_eq!(label as usize, mlp.classify(&pixels[i], &model));
    }
    assert!(rx.recv().is_err(), "connection closes after the drain");
    server.shutdown();
}

#[test]
fn shard_sweep_is_bit_identical_with_correct_admission_totals() {
    // The sharded batcher must be invisible to clients: for shards in
    // {1, 2, 4} the same requests produce byte-identical logits (and
    // match the functional model), every request is admitted exactly
    // once, and the per-request responses remain correctly paired under
    // pipelined (out-of-order-completion) traffic.
    let mlp = QuantMlp::random_digits(83);
    let model = MultiplierModel::new(MultiplierKind::DncOpt);
    let n = 24usize;
    let mut baseline: Option<Vec<Vec<f32>>> = None;
    for shards in [1usize, 2, 4] {
        let (server, handle, net, pixels) = start_stack("net-shards", &mlp, |cfg| {
            cfg.batcher.shards = shards;
            cfg.batcher.max_wait_us = 1_000;
        });
        assert_eq!(handle.shards(), shards);
        let client = NetClient::connect(net.local_addr()).unwrap();
        let (mut tx, mut rx, _info) = client.split();
        // pipelined: all n requests in flight at once, spread across
        // every shard by the id-affine dispatch
        for px in pixels.iter().cycle().take(n) {
            tx.send(px).unwrap();
        }
        let mut got: Vec<Option<Vec<f32>>> = vec![None; n];
        for _ in 0..n {
            match rx.recv().unwrap() {
                Frame::Response { id, logits, .. } => {
                    assert!(got[id as usize].is_none(), "duplicate reply for {id}");
                    got[id as usize] = Some(logits.take());
                }
                other => panic!("unexpected {other:?} at {shards} shards"),
            }
        }
        let logits: Vec<Vec<f32>> =
            got.into_iter().map(|g| g.expect("every request answered")).collect();
        for (i, lg) in logits.iter().enumerate() {
            let want = mlp.forward(&pixels[i % pixels.len()], &model);
            assert_eq!(lg, &want, "shards {shards} request {i} diverged from the model");
        }
        match &baseline {
            None => baseline = Some(logits),
            Some(base) => {
                assert_eq!(&logits, base, "{shards} shards diverged from the 1-shard replies");
            }
        }
        let snap = handle.metrics().snapshot();
        assert_eq!(snap.accepted, n as u64, "{shards} shards admission total");
        assert_eq!(snap.rejected, 0, "{shards} shards spurious rejections");
        assert_eq!(snap.requests, n as u64, "{shards} shards served total");
        net.shutdown();
        server.shutdown();
    }
}

#[test]
fn sharded_admission_bound_stays_global() {
    // queue_depth must bound *total* outstanding across all shards, not
    // per shard: with 4 shards and queue_depth 2, a third concurrent
    // request is rejected no matter which shard it would land on.
    let mlp = QuantMlp::random_digits(89);
    let (server, handle, net, pixels) = start_stack("net-shards-admit", &mlp, |cfg| {
        cfg.batcher.shards = 4;
        cfg.batcher.queue_depth = 2;
        cfg.batcher.max_wait_us = 500_000; // hold the first two in the batchers
    });
    let client = NetClient::connect(net.local_addr()).unwrap();
    let (mut tx, mut rx, _info) = client.split();
    tx.send(&pixels[0]).unwrap();
    tx.send(&pixels[1]).unwrap();
    wait_accepted(&handle, 2);
    let err = handle.submit(pixels[2].clone()).expect_err("global bound reached");
    let bp = err.downcast_ref::<Backpressure>().expect("typed backpressure");
    assert!(bp.retry_after_us >= 1);
    let snap = handle.metrics().snapshot();
    assert_eq!(snap.accepted, 2);
    assert_eq!(snap.rejected, 1);
    // drain so shutdown is clean
    net.shutdown();
    for _ in 0..2 {
        assert!(matches!(rx.recv().unwrap(), Frame::Response { .. }));
    }
    server.shutdown();
}

#[test]
fn connection_cap_turns_away_with_rejected_frame() {
    let mlp = QuantMlp::random_digits(79);
    let (store, _testset) = synth_artifacts("net-cap", &mlp, 8);
    let mut cfg = Config::default();
    cfg.artifacts_dir = store.root().display().to_string();
    let (server, handle) = CoordinatorServer::start(cfg).unwrap();
    let net = NetServer::bind(handle.clone(), "127.0.0.1:0", 1).unwrap();
    let first = NetClient::connect(net.local_addr()).unwrap();
    assert_eq!(net.live_connections(), 1);
    let err = NetClient::connect(net.local_addr()).expect_err("over the cap");
    assert!(format!("{err:#}").contains("connection limit"), "{err:#}");
    let snap = handle.metrics().snapshot();
    assert_eq!(snap.rejected, 1);
    assert_eq!(snap.retry_hints, 0, "connection turn-away has no queue-derived hint");
    drop(first);
    net.shutdown();
    server.shutdown();
}

/// Router config over the given backend addresses, tuned for tests
/// (fast probing, tight backoff).
fn router_cfg(backends: Vec<String>, probe_ms: u64) -> RouterConfig {
    RouterConfig {
        listen: "127.0.0.1:0".into(),
        backends,
        policy: DispatchPolicy::Hash,
        vnodes: 160,
        max_connections: 64,
        probe_ms,
        max_backoff_ms: probe_ms * 5,
    }
}

#[test]
fn router_failover_resolves_every_in_flight_request() {
    // Kill one of two backends while its requests are parked in the
    // batcher. The acceptance bar: *every* in-flight request resolves —
    // a Response from the survivor or a retryable Rejected for the dead
    // backend's — none hang; the failover and quarantine counters match
    // the frames observed; and a retrying loadgen still completes a run
    // through the degraded router.
    let mlp = QuantMlp::random_digits(101);
    let mut servers = Vec::new();
    let mut handles = Vec::new();
    let mut nets: Vec<Option<NetServer>> = Vec::new();
    let mut pixels = Vec::new();
    for tag in ["net-failover-a", "net-failover-b"] {
        let (server, handle, net, px) = start_stack(tag, &mlp, |cfg| {
            // hold requests in flight long enough to die mid-batch
            cfg.batcher.max_wait_us = 400_000;
        });
        servers.push(server);
        handles.push(handle);
        nets.push(Some(net));
        pixels = px;
    }
    let addrs = vec![
        nets[0].as_ref().unwrap().local_addr().to_string(),
        nets[1].as_ref().unwrap().local_addr().to_string(),
    ];
    let router = RouterServer::bind(&router_cfg(addrs, 20)).unwrap();
    assert!(router.backend_connected(0) && router.backend_connected(1));

    // one in-flight request per connection, fanned out by the hash policy
    let n = 6usize;
    let mut conns = Vec::new();
    for i in 0..n {
        let client = NetClient::connect(router.local_addr()).unwrap();
        let (mut tx, rx, _info) = client.split();
        tx.send(&pixels[i % pixels.len()]).unwrap();
        conns.push((tx, rx));
    }
    let resolved = Arc::new(Mutex::new(Vec::new()));
    let mut waiters = Vec::new();
    for (i, (tx, mut rx)) in conns.into_iter().enumerate() {
        let resolved = Arc::clone(&resolved);
        waiters.push(std::thread::spawn(move || {
            let frame = rx.recv();
            resolved.lock().unwrap().push((i, frame));
            drop(tx); // keep the write half open until resolution
        }));
    }
    let t0 = Instant::now();
    loop {
        let total: u64 = handles.iter().map(|h| h.metrics().snapshot().accepted).sum();
        if total == n as u64 {
            break;
        }
        assert!(t0.elapsed() < Duration::from_secs(5), "requests never admitted");
        std::thread::yield_now();
    }
    let snap = router.metrics().snapshot();
    assert_eq!(snap.routed_total(), n as u64);
    let victim = if snap.backends[0].routed >= snap.backends[1].routed { 0 } else { 1 };
    let survivor = 1 - victim;
    assert!(snap.backends[victim].routed > 0);
    nets[victim].take().unwrap().abort();

    let t0 = Instant::now();
    while resolved.lock().unwrap().len() < n {
        assert!(t0.elapsed() < Duration::from_secs(15), "in-flight request hung in failover");
        std::thread::sleep(Duration::from_millis(5));
    }
    for w in waiters {
        w.join().unwrap();
    }
    let resolved = resolved.lock().unwrap();
    let (mut responses, mut failovers) = (0u64, 0u64);
    for (i, frame) in resolved.iter() {
        match frame {
            Ok(Frame::Response { .. }) => responses += 1,
            Ok(Frame::Rejected { retry_after_us, reason, .. }) => {
                assert!(*retry_after_us >= 1, "failover hint must be actionable");
                assert!(reason.contains("retry"), "{reason}");
                failovers += 1;
            }
            other => panic!("connection {i}: {other:?}"),
        }
    }
    assert_eq!(responses + failovers, n as u64, "every in-flight request resolved");
    assert!(failovers > 0, "the dead backend's requests fail over");

    let snap = router.metrics().snapshot();
    assert_eq!(snap.failed_over_total(), failovers, "counters match the frames observed");
    assert_eq!(snap.backends[victim].failed_over, failovers);
    assert_eq!(snap.quarantines_total(), 1, "exactly the dead backend is quarantined");
    assert_eq!(snap.backends[victim].quarantines, 1);
    assert_eq!(snap.backends[survivor].quarantines, 0);
    assert!(!router.backend_connected(victim));
    assert!(router.backend_connected(survivor));

    // a hint-honoring loadgen run completes against the degraded fleet
    let opts = loadgen::LoadgenOptions {
        scenarios: vec![Scenario::Closed],
        loads: vec![],
        connections: 2,
        requests_per_level: 6,
        burst: 4,
        seed: 7,
        retry: true,
        models: vec![],
        mix: loadgen::ModelMix::Zipf,
    };
    let cases = loadgen::run(&router.local_addr().to_string(), &opts).unwrap();
    assert_eq!(cases.len(), 1);
    assert_eq!(cases[0].ok, cases[0].sent, "retrying loadgen completes every request");
    assert_eq!(cases[0].errors, 0, "no protocol errors through failover");

    router.shutdown();
    nets[survivor].take().unwrap().shutdown();
    for server in servers {
        server.shutdown();
    }
}

#[test]
fn router_quarantines_dead_backend_and_recovers_on_probe() {
    let mlp = QuantMlp::random_digits(103);
    let model = MultiplierModel::new(MultiplierKind::DncOpt);
    let (server_a, _handle_a, net_a, pixels) = start_stack("net-recover-a", &mlp, |cfg| {
        cfg.batcher.max_wait_us = 1_000;
    });
    // reserve an endpoint that refuses connections until B binds it
    let reserve = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let dead_addr = reserve.local_addr().unwrap().to_string();
    drop(reserve);
    let addrs = vec![net_a.local_addr().to_string(), dead_addr.clone()];
    let router = RouterServer::bind(&router_cfg(addrs, 10)).unwrap();
    assert!(router.backend_connected(0));
    assert!(!router.backend_connected(1));

    // the healthy half serves through the router meanwhile
    let mut client = NetClient::connect(router.local_addr()).unwrap();
    match client.infer(&pixels[0]).unwrap() {
        Frame::Response { logits, .. } => assert_eq!(logits, mlp.forward(&pixels[0], &model)),
        other => panic!("unexpected {other:?}"),
    }
    let snap = router.metrics().snapshot();
    assert_eq!(snap.backends[1].quarantines, 1, "dead endpoint is quarantined");
    assert_eq!(snap.backends[1].recoveries, 0);
    assert_eq!(snap.backends[1].routed, 0, "nothing routed to a quarantined backend");

    // stand a second backend up on the quarantined endpoint
    let (store_b, _testset) = synth_artifacts("net-recover-b", &mlp, 8);
    let mut cfg_b = Config::default();
    cfg_b.artifacts_dir = store_b.root().display().to_string();
    cfg_b.batcher.max_wait_us = 1_000;
    let (server_b, handle_b) = CoordinatorServer::start(cfg_b).unwrap();
    let net_b = NetServer::bind(handle_b.clone(), &dead_addr, 64).unwrap();

    let t0 = Instant::now();
    while router.metrics().snapshot().backends[1].recoveries < 1 {
        assert!(t0.elapsed() < Duration::from_secs(10), "probe never recovered the backend");
        std::thread::sleep(Duration::from_millis(5));
    }
    assert!(router.backend_connected(1));

    // fresh connections (new conn keys) eventually hash onto the
    // recovered backend, proving it is back in rotation
    let mut hit = false;
    for i in 0..32 {
        let mut c = NetClient::connect(router.local_addr()).unwrap();
        assert!(matches!(c.infer(&pixels[i % pixels.len()]).unwrap(), Frame::Response { .. }));
        if router.metrics().snapshot().backends[1].routed > 0 {
            hit = true;
            break;
        }
    }
    assert!(hit, "no connection ever hashed onto the recovered backend");
    let snap = router.metrics().snapshot();
    assert_eq!(snap.backends[1].quarantines, snap.backends[1].recoveries);
    router.shutdown();
    net_a.shutdown();
    net_b.shutdown();
    server_a.shutdown();
    server_b.shutdown();
}

#[test]
fn connection_affinity_is_bit_identical_across_shard_counts() {
    // `batcher.affinity = connection` pins each connection's requests to
    // one batcher lane. Like the request-affine default it must be
    // invisible in the replies: byte-identical logits for shards in
    // {1, 2, 4} under pipelined multi-connection traffic.
    let mlp = QuantMlp::random_digits(97);
    let model = MultiplierModel::new(MultiplierKind::DncOpt);
    let per_conn = 8usize;
    let mut baseline: Option<Vec<Vec<f32>>> = None;
    for shards in [1usize, 2, 4] {
        let (server, handle, net, pixels) = start_stack("net-affinity", &mlp, |cfg| {
            cfg.batcher.shards = shards;
            cfg.batcher.affinity = ShardAffinity::Connection;
            cfg.batcher.max_wait_us = 1_000;
        });
        let mut all = Vec::new();
        for conn in 0..3usize {
            let client = NetClient::connect(net.local_addr()).unwrap();
            let (mut tx, mut rx, _info) = client.split();
            for i in 0..per_conn {
                tx.send(&pixels[(conn * per_conn + i) % pixels.len()]).unwrap();
            }
            let mut got: Vec<Option<Vec<f32>>> = vec![None; per_conn];
            for _ in 0..per_conn {
                match rx.recv().unwrap() {
                    Frame::Response { id, logits, .. } => {
                        assert!(got[id as usize].is_none(), "duplicate reply for {id}");
                        got[id as usize] = Some(logits.take());
                    }
                    other => panic!("unexpected {other:?} at {shards} shards"),
                }
            }
            for (i, g) in got.into_iter().enumerate() {
                let lg = g.expect("every request answered");
                let want = mlp.forward(&pixels[(conn * per_conn + i) % pixels.len()], &model);
                assert_eq!(lg, want, "shards {shards} conn {conn} request {i} diverged");
                all.push(lg);
            }
        }
        match &baseline {
            None => baseline = Some(all),
            Some(base) => {
                assert_eq!(&all, base, "connection affinity diverged at {shards} shards");
            }
        }
        let snap = handle.metrics().snapshot();
        assert_eq!(snap.accepted, (3 * per_conn) as u64, "{shards} shards admission total");
        assert_eq!(snap.rejected, 0, "{shards} shards spurious rejections");
        net.shutdown();
        server.shutdown();
    }
}

#[test]
fn multi_tenant_replies_bit_identical_across_shards_and_plan_threads() {
    // Model-tagged serving must be invisible everywhere the plan can
    // vary: for shards {1, 2} × gemm threads {1, 2} the same two-tenant
    // request stream produces byte-identical logits — cold compile on
    // the first tenant touch, plan-cache hits after — both on the wire
    // and through the in-process submit path.
    let mlp_a = QuantMlp::random_digits(111);
    let mlp_b = QuantMlp::random_digits(112);
    let model = MultiplierModel::new(MultiplierKind::DncOpt);
    let m1 = ModelId::new("m1").unwrap();
    let (store_b, _testset) = synth_artifacts("net-mt-b", &mlp_b, 8);
    let dir_b = store_b.root().display().to_string();
    let mut baseline: Option<Vec<Vec<f32>>> = None;
    for shards in [1usize, 2] {
        for threads in [1usize, 2] {
            let (server, handle, net, pixels) = start_stack("net-mt-a", &mlp_a, |cfg| {
                cfg.batcher.shards = shards;
                cfg.batcher.max_wait_us = 1_000;
                cfg.gemm.threads = threads;
                cfg.serving.models = vec![("m1".to_string(), dir_b.clone())];
            });
            let mut client = NetClient::connect(net.local_addr()).unwrap();
            assert_eq!(client.info().models, vec!["m1".to_string()]);
            let mut all = Vec::new();
            for (i, px) in pixels.iter().take(6).enumerate() {
                let wire_b = match client.infer_model(m1, px).unwrap() {
                    Frame::Response { logits, .. } => logits.take(),
                    other => panic!("tenant request {i}: {other:?}"),
                };
                assert_eq!(wire_b, mlp_b.forward(px, &model), "m1 diverged (request {i})");
                let wire_a = match client.infer(px).unwrap() {
                    Frame::Response { logits, .. } => logits.take(),
                    other => panic!("default request {i}: {other:?}"),
                };
                assert_eq!(wire_a, mlp_a.forward(px, &model), "default diverged (request {i})");
                let direct = handle.submit_model(m1, px.clone()).unwrap();
                assert_eq!(direct.logits, wire_b, "in-process m1 diverged from the wire");
                all.push(wire_a);
                all.push(wire_b);
            }
            let snap = handle.metrics().snapshot();
            assert!(snap.plan_hits > 0, "warm tenant requests must hit the plan cache");
            assert_eq!(snap.plan_evictions, 0, "the default budget fits both tenants");
            assert!(handle.model_stats(m1).unwrap().requests >= 1, "per-model stats exist");
            match &baseline {
                None => baseline = Some(all),
                Some(base) => {
                    assert_eq!(&all, base, "shards {shards} threads {threads} diverged");
                }
            }
            net.shutdown();
            server.shutdown();
        }
    }
}

#[test]
fn plan_eviction_and_recompile_stay_bit_identical() {
    // A one-entry plan-cache budget makes the two tenants evict each
    // other on every alternation; each recompile must reproduce the
    // evicted plan's replies bit for bit.
    let mlp_a = QuantMlp::random_digits(115);
    let mlp_b = QuantMlp::random_digits(116);
    let model = MultiplierModel::new(MultiplierKind::DncOpt);
    let m1 = ModelId::new("m1").unwrap();
    let (store_b, _testset) = synth_artifacts("net-evict-b", &mlp_b, 8);
    let dir_b = store_b.root().display().to_string();
    let gemm = GemmOptions::default();
    let one = ModelEntry::compile(ModelId::DEFAULT, mlp_a.clone(), gemm)
        .bytes
        .max(ModelEntry::compile(ModelId::DEFAULT, mlp_b.clone(), gemm).bytes);
    let (server, handle, net, pixels) = start_stack("net-evict-a", &mlp_a, |cfg| {
        cfg.batcher.max_wait_us = 1_000;
        cfg.serving.models = vec![("m1".to_string(), dir_b.clone())];
        cfg.plan_cache.max_bytes = one + one / 2; // room for one tenant
    });
    let mut client = NetClient::connect(net.local_addr()).unwrap();
    let px = &pixels[0];
    let mut first: Option<(Vec<f32>, Vec<f32>)> = None;
    for round in 0..3 {
        let a = match client.infer(px).unwrap() {
            Frame::Response { logits, .. } => logits.take(),
            other => panic!("round {round} default: {other:?}"),
        };
        let b = match client.infer_model(m1, px).unwrap() {
            Frame::Response { logits, .. } => logits.take(),
            other => panic!("round {round} m1: {other:?}"),
        };
        assert_eq!(a, mlp_a.forward(px, &model), "round {round}: default diverged");
        assert_eq!(b, mlp_b.forward(px, &model), "round {round}: m1 diverged");
        match &first {
            None => first = Some((a, b)),
            Some((fa, fb)) => {
                assert_eq!(&a, fa, "round {round}: recompiled default diverged");
                assert_eq!(&b, fb, "round {round}: recompiled m1 diverged");
            }
        }
    }
    let snap = handle.metrics().snapshot();
    assert!(snap.plan_evictions >= 2, "tenants must evict each other under a one-entry budget");
    assert!(snap.plan_compiles >= 4, "every eviction forces a later recompile");
    assert_eq!(snap.plan_resident, 1, "exactly one tenant fits");
    assert!(snap.plan_resident_bytes <= (one + one / 2) as u64, "budget invariant on the gauge");
    net.shutdown();
    server.shutdown();
}

#[test]
fn router_serves_model_tagged_requests_bit_identically() {
    // The fleet model-set agreement makes model-tagged requests safe
    // wherever the hash policy lands them: every connection through the
    // router gets bit-exact replies for both tenants, and the fleet
    // `Info` advertises the agreed model list.
    let mlp_a = QuantMlp::random_digits(113);
    let mlp_b = QuantMlp::random_digits(114);
    let model = MultiplierModel::new(MultiplierKind::DncOpt);
    let m1 = ModelId::new("m1").unwrap();
    let (store_b, _testset) = synth_artifacts("net-mt-router-b", &mlp_b, 8);
    let dir_b = store_b.root().display().to_string();
    let mut servers = Vec::new();
    let mut nets = Vec::new();
    let mut addrs = Vec::new();
    let mut pixels = Vec::new();
    for tag in ["net-mt-router-0", "net-mt-router-1"] {
        let (server, _handle, net, px) = start_stack(tag, &mlp_a, |cfg| {
            cfg.batcher.max_wait_us = 1_000;
            cfg.serving.models = vec![("m1".to_string(), dir_b.clone())];
        });
        addrs.push(net.local_addr().to_string());
        servers.push(server);
        nets.push(net);
        pixels = px;
    }
    let router = RouterServer::bind(&router_cfg(addrs, 20)).unwrap();
    assert!(router.backend_connected(0) && router.backend_connected(1));
    for i in 0..6 {
        let mut client = NetClient::connect(router.local_addr()).unwrap();
        assert_eq!(client.info().models, vec!["m1".to_string()], "fleet-agreed model set");
        let px = &pixels[i % pixels.len()];
        match client.infer_model(m1, px).unwrap() {
            Frame::Response { logits, .. } => {
                assert_eq!(logits.take(), mlp_b.forward(px, &model), "conn {i} m1 diverged")
            }
            other => panic!("conn {i} m1: {other:?}"),
        }
        match client.infer(px).unwrap() {
            Frame::Response { logits, .. } => {
                assert_eq!(logits.take(), mlp_a.forward(px, &model), "conn {i} default diverged")
            }
            other => panic!("conn {i} default: {other:?}"),
        }
    }
    assert_eq!(router.metrics().snapshot().routed_total(), 12);
    router.shutdown();
    for net in nets {
        net.shutdown();
    }
    for server in servers {
        server.shutdown();
    }
}

#[test]
fn hot_swap_under_live_load_drops_no_connection_and_drains_in_flight() {
    // The acceptance bar for hot swap: `LoadModel` then `RetireModel`
    // while requests are genuinely in flight drops no connection and
    // resolves every in-flight request; the retire ack arrives only
    // after the drain; a retiring model's new requests come back as
    // retryable `Rejected`; and reloading the id serves the *new*
    // weights (the retired plan really left the cache).
    let mlp_a = QuantMlp::random_digits(121);
    let mlp_b = QuantMlp::random_digits(122);
    let mlp_c = QuantMlp::random_digits(123);
    let model = MultiplierModel::new(MultiplierKind::DncOpt);
    let hot = ModelId::new("hot").unwrap();
    let (store_b, _ts_b) = synth_artifacts("net-swap-b", &mlp_b, 8);
    let (store_c, _ts_c) = synth_artifacts("net-swap-c", &mlp_c, 8);
    let dir_b = store_b.root().display().to_string();
    let dir_c = store_c.root().display().to_string();
    let (server, handle, net, pixels) = start_stack("net-swap-a", &mlp_a, |cfg| {
        // in-flight requests park in the batcher until the deadline
        // flush — live load genuinely spans the swap window
        cfg.batcher.max_wait_us = 150_000;
    });

    // live default-model traffic, parked in the batcher
    let live = NetClient::connect(net.local_addr()).unwrap();
    let (mut live_tx, mut live_rx, info) = live.split();
    assert!(info.models.is_empty(), "no extra models before the load");
    for px in pixels.iter().take(3) {
        live_tx.send(px).unwrap();
    }
    wait_accepted(&handle, 3);

    // hot-load the second tenant while those are in flight
    let mut admin = NetClient::connect(net.local_addr()).unwrap();
    admin.load_model(hot, &dir_b).unwrap();
    let mut probe = NetClient::connect(net.local_addr()).unwrap();
    assert_eq!(probe.info().models, vec!["hot".to_string()], "fresh handshakes see the load");
    match probe.infer_model(hot, &pixels[0]).unwrap() {
        Frame::Response { logits, .. } => {
            assert_eq!(logits.take(), mlp_b.forward(&pixels[0], &model), "cold compile serves")
        }
        other => panic!("hot model after load: {other:?}"),
    }

    // park in-flight requests on the model about to retire
    let park = NetClient::connect(net.local_addr()).unwrap();
    let (mut park_tx, mut park_rx, _info) = park.split();
    for px in pixels.iter().take(3) {
        park_tx.send_model(hot, px).unwrap();
    }
    wait_accepted(&handle, 7);

    // retire on its own admin connection: the ack blocks on the drain
    let retirer = std::thread::spawn({
        let addr = net.local_addr();
        move || {
            let mut admin2 = NetClient::connect(addr).unwrap();
            admin2.retire_model(hot).unwrap();
        }
    });
    // while the drain is pending, new requests for the retiring model
    // come back as retryable Rejected — not dropped, not an Error
    std::thread::sleep(Duration::from_millis(30));
    match probe.infer_model(hot, &pixels[1]).unwrap() {
        Frame::Rejected { reason, .. } => assert!(reason.contains("retiring"), "{reason}"),
        other => panic!("request during retire drain: {other:?}"),
    }
    retirer.join().unwrap();

    // every parked request on the retired model resolved with its reply
    let mut got: Vec<Option<Vec<f32>>> = vec![None; 3];
    for _ in 0..3 {
        match park_rx.recv().unwrap() {
            Frame::Response { id, logits, .. } => got[id as usize] = Some(logits.take()),
            other => panic!("in-flight request lost in the swap: {other:?}"),
        }
    }
    for (i, g) in got.into_iter().enumerate() {
        let want = mlp_b.forward(&pixels[i], &model);
        assert_eq!(g.expect("every in-flight request resolves"), want, "parked request {i}");
    }
    // ... and the live default-model connection never noticed the swap
    let mut got: Vec<Option<Vec<f32>>> = vec![None; 3];
    for _ in 0..3 {
        match live_rx.recv().unwrap() {
            Frame::Response { id, logits, .. } => got[id as usize] = Some(logits.take()),
            other => panic!("live default request lost in the swap: {other:?}"),
        }
    }
    for (i, g) in got.into_iter().enumerate() {
        let want = mlp_a.forward(&pixels[i], &model);
        assert_eq!(g.expect("live request resolves"), want, "live request {i}");
    }
    live_tx.send(&pixels[3]).unwrap();
    match live_rx.recv().unwrap() {
        Frame::Response { id, logits, .. } => {
            assert_eq!(id, 3);
            assert_eq!(logits.take(), mlp_a.forward(&pixels[3], &model), "post-swap traffic");
        }
        other => panic!("live connection broken after the swap: {other:?}"),
    }

    // the retired id is gone (terminal Error), and reloading it serves
    // the *new* artifacts — the old plan really left the cache
    match probe.infer_model(hot, &pixels[0]).unwrap() {
        Frame::Error { reason, .. } => assert!(reason.contains("not being served"), "{reason}"),
        other => panic!("retired model request: {other:?}"),
    }
    admin.load_model(hot, &dir_c).unwrap();
    match probe.infer_model(hot, &pixels[0]).unwrap() {
        Frame::Response { logits, .. } => {
            let got = logits.take();
            assert_eq!(got, mlp_c.forward(&pixels[0], &model), "swapped-in weights serve");
            assert_ne!(got, mlp_b.forward(&pixels[0], &model), "the old weights are gone");
        }
        other => panic!("hot model after swap: {other:?}"),
    }
    net.shutdown();
    server.shutdown();
}

#[test]
fn routed_trace_stitches_router_and_backend_spans_into_one_timeline() {
    // The tracing acceptance bar: one explicitly traced request through
    // the router leaves spans in two flight recorders — the router's
    // (ingress, write_back) and the backend's (ingress → write_back) —
    // and the two wire-dumped Chrome traces merge into one timeline
    // keyed by the single wire-carried trace id, in pipeline order.
    // Router sampling is *off*, so the spans also pin "a nonzero wire
    // id is honored as-is, never reassigned".
    let mlp = QuantMlp::random_digits(131);
    let model = MultiplierModel::new(MultiplierKind::DncOpt);
    let (server, _handle, net, pixels) = start_stack("net-trace", &mlp, |cfg| {
        cfg.batcher.max_wait_us = 1_000;
    });
    let trace_cfg = TraceConfig { sample_every: 0, ..TraceConfig::default() };
    let rcfg = router_cfg(vec![net.local_addr().to_string()], 20);
    let router = RouterServer::bind_traced(&rcfg, &trace_cfg).unwrap();
    assert!(router.backend_connected(0));

    let client = NetClient::connect(router.local_addr()).unwrap();
    let (mut tx, mut rx, _info) = client.split();
    let trace_id: u64 = 0x00C0_FFEE;
    tx.send_traced(ModelId::DEFAULT, &pixels[0], trace_id).unwrap();
    match rx.recv().unwrap() {
        Frame::Response { logits, trace, .. } => {
            assert_eq!(logits.take(), mlp.forward(&pixels[0], &model));
            assert_eq!(trace, trace_id, "the reply echoes the wire trace id");
        }
        other => panic!("unexpected {other:?}"),
    }

    // both write_back spans land moments after the reply is forwarded —
    // poll the wire dumps (`DumpTrace` on each tier) until they show
    let want = format!("{trace_id:#018x}");
    let t0 = Instant::now();
    let spans = loop {
        let rd = NetClient::connect(router.local_addr()).unwrap().dump_trace().unwrap();
        let bd = NetClient::connect(net.local_addr()).unwrap().dump_trace().unwrap();
        let merged = merge_trace_dumps(&[rd, bd]);
        let mut spans: Vec<_> =
            parse_trace_json(&merged).into_iter().filter(|e| e.trace == want).collect();
        spans.sort_by_key(|e| e.ts);
        if spans.iter().filter(|e| e.name == "write_back").count() == 2 {
            break spans;
        }
        assert!(t0.elapsed() < Duration::from_secs(5), "spans never landed: {spans:?}");
        std::thread::sleep(Duration::from_millis(5));
    };

    // two recorders contributed to the one trace id (the in-process
    // fleet shares a pid; Chrome tids keep the tiers apart)
    let mut tids: Vec<u64> = spans.iter().map(|e| e.tid).collect();
    tids.sort_unstable();
    tids.dedup();
    assert_eq!(tids.len(), 2, "router and backend recorders both contributed");
    let backend_tid = spans.iter().find(|e| e.name == "gemm").expect("gemm span").tid;
    let router_tid = *tids.iter().find(|t| **t != backend_tid).unwrap();

    let ts_of = |tid: u64, name: &str| {
        spans.iter().find(|e| e.tid == tid && e.name == name).map(|e| e.ts)
    };
    // the backend recorded the full pipeline, in order
    let order = ["ingress", "admission", "queue_wait", "batch_form", "gemm", "write_back"];
    let mut prev = 0u64;
    for name in order {
        let ts = ts_of(backend_tid, name)
            .unwrap_or_else(|| panic!("backend span {name} missing: {spans:?}"));
        assert!(ts >= prev, "backend {name} out of pipeline order");
        prev = ts;
    }
    // the router's ingress opens the timeline and its write_back closes
    // it, bracketing the backend's stages (coarse cross-recorder bounds:
    // the 1 ms batching deadline dwarfs any wall-clock anchor skew)
    let r_in = ts_of(router_tid, "ingress").expect("router ingress span");
    let r_wb = ts_of(router_tid, "write_back").expect("router write_back span");
    assert!(r_in <= ts_of(backend_tid, "gemm").unwrap(), "router ingress opens the timeline");
    assert!(r_wb >= ts_of(backend_tid, "queue_wait").unwrap(), "router write_back closes it");

    router.shutdown();
    net.shutdown();
    server.shutdown();
}

/// Normalize the two documented scrape-vs-snapshot divergences away:
/// `throughput_rps` depends on the wall clock at snapshot time, and the
/// buffer pool is process-wide (every other test in this binary churns
/// it). Everything else must match exactly on a quiesced server.
fn normalized(mut s: MetricsSnapshot) -> MetricsSnapshot {
    s.throughput_rps = 0.0;
    s.pool = PoolStats { hits: 0, misses: 0, recycled: 0 };
    s
}

/// Poll until two consecutive normalized snapshots agree — the last
/// write-back counters land moments after the last reply is received.
fn quiesced_snapshot(handle: &ServerHandle) -> MetricsSnapshot {
    let t0 = Instant::now();
    loop {
        let a = normalized(handle.metrics().snapshot());
        std::thread::sleep(Duration::from_millis(2));
        let b = normalized(handle.metrics().snapshot());
        if a == b {
            return b;
        }
        assert!(t0.elapsed() < Duration::from_secs(5), "metrics never quiesced");
    }
}

#[test]
fn wire_stats_scrape_matches_in_process_snapshot_and_fans_out_via_router() {
    // `GetStats` must return the same numbers the in-process snapshot
    // shows once the server is quiesced (modulo the documented
    // divergences `normalized` strips), and scraping a *router* must
    // return its RouterSnapshot plus one fanned-out backend snapshot
    // per reachable backend.
    let mlp = QuantMlp::random_digits(137);
    let (server, handle, net, pixels) = start_stack("net-scrape", &mlp, |cfg| {
        cfg.batcher.max_wait_us = 1_000;
    });
    let mut client = NetClient::connect(net.local_addr()).unwrap();
    for px in pixels.iter().take(5) {
        assert!(matches!(client.infer(px).unwrap(), Frame::Response { .. }));
    }
    let local = quiesced_snapshot(&handle);
    let payload = client.get_stats().unwrap();
    assert!(payload.router.is_none(), "a plain server has no router tier");
    assert!(payload.backends.is_empty(), "a plain server fans out to nobody");
    let wire = normalized(payload.server.expect("server snapshot on the wire"));
    assert_eq!(wire, local, "wire scrape equals the in-process snapshot");
    assert_eq!(wire.requests, 5);
    assert_eq!(wire.stage_count[0], 5, "ingress histogram: one sample per wire request");
    assert!(wire.stage_p99_us[2] >= wire.stage_p50_us[2], "queue-wait percentiles ordered");

    // the same scrape through a router: RouterSnapshot + backend fan-out
    let router = RouterServer::bind(&router_cfg(vec![net.local_addr().to_string()], 20)).unwrap();
    assert!(router.backend_connected(0));
    let mut rclient = NetClient::connect(router.local_addr()).unwrap();
    for px in pixels.iter().take(2) {
        assert!(matches!(rclient.infer(px).unwrap(), Frame::Response { .. }));
    }
    let local = quiesced_snapshot(&handle);
    let payload = rclient.get_stats().unwrap();
    assert!(payload.server.is_none(), "a router has no server-side snapshot");
    let rsnap = payload.router.expect("router snapshot on the wire");
    assert_eq!(rsnap.routed_total(), 2);
    assert_eq!(payload.backends.len(), 1, "fan-out reaches the one backend");
    let (baddr, bsnap) = &payload.backends[0];
    assert_eq!(baddr, &net.local_addr().to_string());
    assert_eq!(normalized(bsnap.clone()), local, "fanned-out backend snapshot matches");
    assert_eq!(bsnap.requests, 7, "5 direct + 2 routed requests");

    router.shutdown();
    net.shutdown();
    server.shutdown();
}

#[test]
fn v02_client_frames_are_served_unchanged_by_a_v03_server() {
    // The minor bumped to 3 (trailing trace ids, stats/trace frames); a
    // v0.2 client — strict decode, no trace field anywhere — must keep
    // working against a new server completely unchanged.
    let mlp = QuantMlp::random_digits(139);
    let model = MultiplierModel::new(MultiplierKind::DncOpt);
    let (server, _handle, net, pixels) = start_stack("net-v02", &mlp, |cfg| {
        cfg.batcher.max_wait_us = 1_000;
    });
    let mut s = TcpStream::connect(net.local_addr()).unwrap();
    // hand-rolled v0.2 Hello: the handshake predates the trace fields
    s.write_all(&[MAGIC[0], MAGIC[1], 0x02, 0x05, 0, 0, 0, 0]).unwrap();
    match read_frame(&mut s).unwrap() {
        Some(Frame::Info { .. }) => {}
        other => panic!("v0.2 Hello answered with {other:?}"),
    }
    // hand-rolled v0.2 Request: id + count + pixels — no model, no trace
    let mut payload = Vec::new();
    payload.extend_from_slice(&7u64.to_le_bytes());
    payload.extend_from_slice(&(pixels[0].len() as u32).to_le_bytes());
    for px in &pixels[0] {
        payload.extend_from_slice(&px.to_le_bytes());
    }
    let mut frame = vec![MAGIC[0], MAGIC[1], 0x02, 0x01];
    frame.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    frame.extend_from_slice(&payload);
    s.write_all(&frame).unwrap();
    match read_frame(&mut s).unwrap() {
        Some(Frame::Response { id, logits, .. }) => {
            assert_eq!(id, 7, "the v0.2-assigned id is echoed");
            assert_eq!(logits.take(), mlp.forward(&pixels[0], &model), "bit-exact for v0.2");
        }
        other => panic!("v0.2 Request answered with {other:?}"),
    }
    net.shutdown();
    server.shutdown();
}
