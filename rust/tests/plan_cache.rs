//! Seeded property tests for the multi-tenant compiled-plan cache
//! ([`luna_cim::engine::PlanCache`]).
//!
//! The cache's unit tests pin single behaviors; this suite drives the
//! invariants under *randomized but reproducible* operation sequences:
//!
//! * the byte budget is never exceeded, at any point of any get/retire
//!   interleaving;
//! * eviction is exactly LRU — the resident set tracks a reference
//!   recency-list model op for op;
//! * single-flight compilation holds per model under concurrent cold
//!   misses;
//! * a cached plan and a recompiled plan (after retire) produce
//!   bit-identical logits for **every** [`MultiplierKind`], matching
//!   the functional model row for row.

use luna_cim::engine::{ModelEntry, PlanCache};
use luna_cim::multiplier::{MultiplierKind, MultiplierModel};
use luna_cim::net::ModelId;
use luna_cim::nn::{GemmOptions, QuantMlp};
use luna_cim::util::Rng;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

fn mid(s: &str) -> ModelId {
    ModelId::new(s).unwrap()
}

/// Tenant `k`'s entry: a deterministic digits model per tenant index,
/// so recompiles of the same tenant are bit-identical by construction.
fn tenant_entry(k: usize) -> ModelEntry {
    let name = format!("m{k}");
    let gemm = GemmOptions::default();
    ModelEntry::compile(mid(&name), QuantMlp::random_digits(1000 + k as u64), gemm)
}

#[test]
fn byte_budget_never_exceeded_under_random_churn() {
    let one = tenant_entry(0).bytes;
    // room for three of eight tenants: most inserts must evict
    let cache = PlanCache::standalone(3 * one + one / 2);
    let mut rng = Rng::seed_from_u64(42);
    for step in 0..400 {
        let k = rng.gen_range_u64(0, 8) as usize;
        let model = mid(&format!("m{k}"));
        if rng.gen_f64() < 0.15 {
            cache.retire(model);
        } else {
            let e = cache.get_or_compile(model, || Ok(tenant_entry(k))).unwrap();
            assert_eq!(e.model, model);
            assert_eq!(e.bytes, one, "all digit tenants weigh the same");
        }
        assert!(
            cache.resident_bytes() <= cache.max_bytes(),
            "step {step}: budget invariant broken ({} > {})",
            cache.resident_bytes(),
            cache.max_bytes()
        );
    }
    let c = cache.counters();
    assert!(c.evictions() > 0, "the churn must actually evict");
    assert!(c.hits() > 0 && c.misses() > 0, "the trace must mix hits and misses");
    assert!(c.compiles() >= c.evictions(), "evictions cannot outnumber the inserts behind them");
}

#[test]
fn eviction_order_tracks_a_reference_lru_model() {
    let one = tenant_entry(0).bytes;
    let cap = 3usize;
    let cache = PlanCache::standalone(cap * one + one / 2);
    let tenants = 6usize;
    let mut rng = Rng::seed_from_u64(7);
    // reference model: resident tenant indices, most recently used last
    let mut recency: Vec<usize> = Vec::new();
    for step in 0..300 {
        let k = rng.gen_range_u64(0, tenants as u64) as usize;
        cache.get_or_compile(mid(&format!("m{k}")), || Ok(tenant_entry(k))).unwrap();
        recency.retain(|&r| r != k);
        recency.push(k);
        if recency.len() > cap {
            recency.remove(0); // the entry LRU must have evicted
        }
        for t in 0..tenants {
            assert_eq!(
                cache.is_resident(mid(&format!("m{t}"))),
                recency.contains(&t),
                "step {step}: tenant m{t} residency diverged from the LRU reference"
            );
        }
    }
    assert_eq!(cache.resident_bytes(), cap * one, "steady state keeps exactly `cap` resident");
}

#[test]
fn single_flight_holds_per_model_under_concurrent_cold_misses() {
    let cache = Arc::new(PlanCache::standalone(64 << 20));
    let models = 3usize;
    let threads_per_model = 4usize;
    let compiles: Vec<AtomicU64> = (0..models).map(|_| AtomicU64::new(0)).collect();
    let compiles = Arc::new(compiles);
    std::thread::scope(|s| {
        for k in 0..models {
            for _ in 0..threads_per_model {
                let cache = Arc::clone(&cache);
                let compiles = Arc::clone(&compiles);
                s.spawn(move || {
                    let e = cache
                        .get_or_compile(mid(&format!("m{k}")), || {
                            // test-only event counter, no publication
                            compiles[k].fetch_add(1, Ordering::Relaxed);
                            std::thread::sleep(std::time::Duration::from_millis(5));
                            Ok(tenant_entry(k))
                        })
                        .unwrap();
                    assert_eq!(e.model, mid(&format!("m{k}")));
                });
            }
        }
    });
    for (k, c) in compiles.iter().enumerate() {
        assert_eq!(c.load(Ordering::Relaxed), 1, "model m{k} compiled more than once");
    }
    let c = cache.counters();
    assert_eq!(c.compiles(), models as u64);
    assert_eq!(
        c.hits() + c.misses(),
        (models * threads_per_model) as u64,
        "every get is either a hit or a miss"
    );
}

#[test]
fn cached_and_recompiled_plans_are_bit_identical_for_every_multiplier() {
    let mlp = QuantMlp::random_digits(77);
    let mut rng = Rng::seed_from_u64(11);
    let batch = 4usize;
    let in_dim = mlp.input_dim();
    let xs: Vec<f32> = (0..batch * in_dim).map(|_| rng.gen_range_f32(0.0, 1.0)).collect();
    let cache = PlanCache::standalone(64 << 20);
    let id = mid("study");
    let one = GemmOptions::default();
    let two = GemmOptions::with_threads(2);
    let cached = cache
        .get_or_compile(id, || Ok(ModelEntry::compile(id, mlp.clone(), one)))
        .unwrap();
    // force the recompile path: retire, then miss again with a
    // different thread plan — results must not depend on either
    assert!(cache.retire(id));
    let recompiled = cache
        .get_or_compile(id, || Ok(ModelEntry::compile(id, mlp.clone(), two)))
        .unwrap();
    assert!(!Arc::ptr_eq(&cached, &recompiled), "retire forces a genuine recompile");
    assert_eq!(cache.counters().compiles(), 2);
    for kind in MultiplierKind::ALL {
        let model = MultiplierModel::new(kind);
        let a = cached.plan.forward_batch(&xs, batch, &model);
        let b = recompiled.plan.forward_batch(&xs, batch, &model);
        assert_eq!(a, b, "{kind:?}: cached vs recompiled plan diverged");
        let out_dim = a.len() / batch;
        for r in 0..batch {
            let want = mlp.forward(&xs[r * in_dim..(r + 1) * in_dim], &model);
            assert_eq!(
                &a[r * out_dim..(r + 1) * out_dim],
                &want[..],
                "{kind:?} row {r}: plan diverged from the functional model"
            );
        }
    }
}
