//! Seeded property tests for the router tier's pure dispatch pieces
//! (`luna_cim::net::router`): consistent-hash balance within the
//! documented imbalance bound, minimal-disruption remapping when a
//! backend dies, the least-outstanding picker's quarantine discipline,
//! and `repro lint` hot-path coverage of the router module itself.
//! Everything here is deterministic (SplitMix64 seeds, and the ring
//! itself is a pure function of its salt) — no sockets, no threads.

use luna_cim::lint::lint_source;
use luna_cim::net::{mix64, pick_least_outstanding, HashRing};
use luna_cim::util::Rng;

/// The `router.vnodes` default; the documented imbalance bound below is
/// stated for this resolution.
const VNODES: usize = 160;

/// Half sequential connection ids (the realistic pattern: a per-router
/// accept counter), half raw 64-bit values — the ring must balance
/// both, since `dispatch` hashes whatever key the policy feeds it.
fn test_keys(count: usize, seed: u64) -> Vec<u64> {
    let mut keys: Vec<u64> = (0..(count / 2) as u64).collect();
    let mut rng = Rng::seed_from_u64(seed);
    while keys.len() < count {
        keys.push(rng.next_u64());
    }
    keys
}

/// Documented bound (crate docs, `## Router tier`): at 160 vnodes every
/// backend's share of a large key population stays within ±25% of the
/// fair share. The ring is deterministic, so this either always holds
/// or never does — the seeds only perturb the key population.
#[test]
fn hash_ring_balances_within_documented_bound() {
    for n in [2usize, 3, 4, 8] {
        let ring = HashRing::new(n, VNODES);
        let keys = test_keys(40_000, 0xC0FF_EE00 + n as u64);
        let mut share = vec![0usize; n];
        for &k in &keys {
            share[ring.pick_where(mix64(k), |_| true).unwrap()] += 1;
        }
        let mean = keys.len() as f64 / n as f64;
        for (b, &s) in share.iter().enumerate() {
            let rel = s as f64 / mean;
            assert!((0.75..=1.25).contains(&rel), "backend {b}/{n}: {rel:.3}x mean ({share:?})");
        }
    }
}

/// Minimal disruption: marking one backend dead remaps *only* the keys
/// it owned (~1/n of the population); every key owned by a live backend
/// keeps its owner, so cache affinity survives a failover.
#[test]
fn removing_a_backend_remaps_only_its_own_keys() {
    for n in [2usize, 3, 4, 8] {
        let ring = HashRing::new(n, VNODES);
        let keys = test_keys(20_000, 0xD15C_0000 + n as u64);
        let dead = n - 1;
        let mut moved = 0usize;
        for &k in &keys {
            let h = mix64(k);
            let before = ring.pick_where(h, |_| true).unwrap();
            let after = ring.pick_where(h, |b| b != dead).unwrap();
            if before == dead {
                moved += 1;
            } else {
                assert_eq!(after, before, "key moved off a live backend (n={n})");
            }
        }
        let frac = moved as f64 / keys.len() as f64;
        let ideal = 1.0 / n as f64;
        assert!(frac >= 0.75 * ideal, "dead backend owned too few keys: {frac:.4} vs {ideal:.4}");
        assert!(frac <= 1.25 * ideal, "dead backend owned too many keys: {frac:.4} vs {ideal:.4}");
    }
}

/// The clockwise walk reaches the sole surviving backend from anywhere
/// on the circle, and only an all-dead fleet yields `None`.
#[test]
fn ring_returns_none_only_when_every_backend_is_dead() {
    let ring = HashRing::new(4, VNODES);
    assert_eq!(ring.pick_where(mix64(7), |_| false), None);
    for survivor in 0..4usize {
        for k in 0..200u64 {
            let pick = ring.pick_where(mix64(k), |b| b == survivor);
            assert_eq!(pick, Some(survivor), "walk must reach the sole live backend");
        }
    }
}

/// The least-outstanding policy never picks a quarantined backend —
/// whatever its load — and among live backends always picks a minimal
/// one. 2000 random fleets of 1..=8 backends.
#[test]
fn least_outstanding_never_picks_a_quarantined_backend() {
    let mut rng = Rng::seed_from_u64(31);
    for _ in 0..2_000 {
        let n = (1 + rng.gen_below(8)) as usize;
        let loads: Vec<u64> = (0..n).map(|_| rng.gen_below(50)).collect();
        let alive: Vec<bool> = (0..n).map(|_| rng.gen_bool(0.7)).collect();
        match pick_least_outstanding(&loads, |b| alive[b]) {
            Some(b) => {
                assert!(alive[b], "picked a quarantined backend");
                let min = (0..n).filter(|&i| alive[i]).map(|i| loads[i]).min().unwrap();
                assert_eq!(loads[b], min, "picked a non-minimal live backend");
            }
            None => assert!(!alive.contains(&true), "returned None with a live backend"),
        }
    }
}

/// `repro lint` polices hot-path modules by path, and the router is one
/// of them: seeded violations under its label must be reported, while
/// the same source under a cold-module label stays clean.
#[test]
fn repro_lint_polices_the_router_as_a_hot_path() {
    let bad_alloc = "fn f() { let v = vec![0u8; 4]; let _ = v; }\n";
    let hits = lint_source("src/net/router.rs", bad_alloc);
    assert!(hits.iter().any(|v| v.rule == "no-bare-alloc"), "router not policed: {hits:?}");

    let bad_mpsc = "use std::sync::mpsc;\n";
    let hits = lint_source("src/net/router.rs", bad_mpsc);
    assert!(hits.iter().any(|v| v.rule == "no-mpsc"), "router not policed for mpsc: {hits:?}");

    assert!(lint_source("src/report.rs", bad_alloc).is_empty());
    assert!(lint_source("src/report.rs", bad_mpsc).is_empty());
}
