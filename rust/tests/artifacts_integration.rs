//! Integration tests over the AOT artifacts (require `make artifacts`).
//!
//! These are the cross-language bit-accuracy checks: the JAX/Pallas
//! kernels (executed through PJRT from the HLO text) must agree with the
//! Rust behavioural models and gate-level netlists.
//!
//! Skipped gracefully when artifacts are missing so plain `cargo test`
//! works before `make artifacts`. The whole file requires the `pjrt`
//! build feature (the default build has no PJRT client).

#![cfg(feature = "pjrt")]

use luna_cim::multiplier::MultiplierKind;
use luna_cim::nn::argmax;
use luna_cim::runtime::{ArtifactStore, PjrtRuntime};

fn store() -> Option<ArtifactStore> {
    // tests run from the crate root
    let s = ArtifactStore::new("artifacts");
    if s.exists() {
        Some(s)
    } else {
        eprintln!("skipping: artifacts/ missing (run `make artifacts`)");
        None
    }
}

/// The full 16x16 operand grids used by the mult_<variant> artifacts.
fn grids() -> (Vec<f32>, Vec<f32>) {
    let mut w = Vec::with_capacity(256);
    let mut y = Vec::with_capacity(256);
    for wi in 0..16 {
        for yi in 0..16 {
            w.push(wi as f32);
            y.push(yi as f32);
        }
    }
    (w, y)
}

#[test]
fn mult_artifacts_match_behavioural_models_exhaustively() {
    let Some(store) = store() else { return };
    let rt = PjrtRuntime::cpu().unwrap();
    let (w, y) = grids();
    for kind in [
        MultiplierKind::Ideal,
        MultiplierKind::Dnc,
        MultiplierKind::DncOpt,
        MultiplierKind::Approx,
        MultiplierKind::Approx2,
    ] {
        let model = rt.load_hlo_text(store.mult_hlo(kind)).unwrap();
        let out = model.run_f32(&[(&w, &[16, 16]), (&y, &[16, 16])]).unwrap();
        assert_eq!(out[0].len(), 256);
        for wi in 0..16u8 {
            for yi in 0..16u8 {
                let got = out[0][(wi as usize) * 16 + yi as usize];
                let want = kind.value(wi, yi) as f32;
                assert_eq!(got, want, "{kind} w={wi} y={yi}");
            }
        }
    }
}

#[test]
fn mult_artifacts_match_gate_level_netlists() {
    let Some(store) = store() else { return };
    let rt = PjrtRuntime::cpu().unwrap();
    let lib = luna_cim::cells::tsmc65_library();
    let (w, y) = grids();
    // DncOpt: PJRT kernel vs the gate-level LUNA unit, all 256 pairs.
    let model = rt.load_hlo_text(store.mult_hlo(MultiplierKind::DncOpt)).unwrap();
    let out = model.run_f32(&[(&w, &[16, 16]), (&y, &[16, 16])]).unwrap();
    let mut unit = luna_cim::luna::LunaUnit::new(MultiplierKind::DncOpt);
    for wi in 0..16u8 {
        unit.program(&lib, wi);
        for yi in 0..16u8 {
            let hw = unit.multiply(&lib, yi);
            let pjrt = out[0][(wi as usize) * 16 + yi as usize];
            assert_eq!(hw as f32, pjrt, "gate-level vs PJRT at w={wi} y={yi}");
        }
    }
}

#[test]
fn mlp_artifact_agrees_with_functional_model() {
    let Some(store) = store() else { return };
    let meta = store.manifest().unwrap();
    let mlp = store.load_mlp().unwrap();
    let testset = store.load_testset().unwrap();
    let rt = PjrtRuntime::cpu().unwrap();

    for kind in [MultiplierKind::Ideal, MultiplierKind::DncOpt, MultiplierKind::Approx] {
        let model = rt.load_hlo_text(store.mlp_hlo(kind)).unwrap();
        let b = meta.batch;
        let in_dim = meta.dims[0];
        let out_dim = *meta.dims.last().unwrap();
        let mut flat = vec![0.0f32; b * in_dim];
        for (i, s) in testset.samples.iter().take(b).enumerate() {
            flat[i * in_dim..(i + 1) * in_dim].copy_from_slice(&s.pixels);
        }
        let out = model.run_f32(&[(&flat, &[b as i64, in_dim as i64])]).unwrap();
        let rust_model = luna_cim::multiplier::MultiplierModel::new(kind);
        let mut label_agree = 0usize;
        let mut max_diff = 0.0f32;
        for i in 0..b {
            let pjrt_logits = &out[0][i * out_dim..(i + 1) * out_dim];
            let rust_logits = mlp.forward(&testset.samples[i].pixels, &rust_model);
            for (a, r) in pjrt_logits.iter().zip(&rust_logits) {
                max_diff = max_diff.max((a - r).abs());
            }
            if argmax(pjrt_logits) == argmax(&rust_logits) {
                label_agree += 1;
            }
        }
        // float32 rounding-mode differences (round-half-even in jnp.round
        // vs half-away in rust) can flip codes on exact ties; logits stay
        // close and labels agree.
        assert!(
            max_diff < 0.75,
            "{kind}: PJRT vs functional logits diverged (max diff {max_diff})"
        );
        assert!(label_agree >= b - 1, "{kind}: only {label_agree}/{b} labels agree");
    }
}

#[test]
fn quantized_accuracy_matches_manifest() {
    let Some(store) = store() else { return };
    let meta = store.manifest().unwrap();
    let mlp = store.load_mlp().unwrap();
    let testset = store.load_testset().unwrap();
    let ideal = luna_cim::multiplier::MultiplierModel::new(MultiplierKind::Ideal);
    let acc = testset.accuracy(|px| mlp.classify(px, &ideal));
    assert!(
        (acc - meta.train_accuracy).abs() < 0.03,
        "functional-model accuracy {acc} vs manifest {}",
        meta.train_accuracy
    );
    assert!(acc > 0.8, "quantized model should classify digits well, got {acc}");
}
