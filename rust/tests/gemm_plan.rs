//! Seeded property suite for the planned LUT-GEMM kernel.
//!
//! The planned kernel (code-sorted weight plans + per-row LUT-strip
//! expansion + a runtime-dispatched strip accumulator + persistent-pool
//! batch tiling, `src/nn/gemm.rs`) must be **bit-exact** with both the
//! per-sample `QuantMlp::forward` and the old flat-gather batched path,
//! for every `MultiplierKind`, every strip kernel × tiling mode ×
//! thread count combination, and arbitrary shapes — including
//! degenerate `1×N` / `N×1` layers and empty/odd/large batches.

use luna_cim::engine::{BackendSpec, ExecBackend};
use luna_cim::multiplier::{MultiplierKind, MultiplierModel};
use luna_cim::nn::{
    BatchScratch, GemmOptions, GemmPartition, GemmSimd, PlanScratch, QuantLinear, QuantMlp,
};
use luna_cim::util::Rng;

/// Random MLP with the given layer dims; ReLU everywhere but the last.
fn random_mlp(rng: &mut Rng, dims: &[usize]) -> QuantMlp {
    assert!(dims.len() >= 2);
    let layers: Vec<QuantLinear> = dims
        .windows(2)
        .enumerate()
        .map(|(i, d)| {
            let (in_dim, out_dim) = (d[0], d[1]);
            let w: Vec<Vec<f32>> = (0..out_dim)
                .map(|_| (0..in_dim).map(|_| rng.gen_range_f32(-0.6, 0.6)).collect())
                .collect();
            let b: Vec<f32> = (0..out_dim).map(|_| rng.gen_range_f32(-0.2, 0.2)).collect();
            // generous x_max keeps deeper activations in quantizer range
            QuantLinear::from_float(&w, b, 1.0 + 2.0 * i as f32, i + 2 < dims.len())
        })
        .collect();
    QuantMlp::new(layers)
}

/// The shape matrix of the suite: degenerate single-row/column layers,
/// a paper-shaped model, a 3-layer chain and an odd in-between.
const DIMS: [&[usize]; 6] = [&[1, 7], &[9, 1], &[1, 1], &[5, 4, 3], &[64, 32, 10], &[33, 17]];

const BATCHES: [usize; 4] = [0, 1, 7, 65];

const THREADS: [usize; 3] = [1, 2, 0]; // 0 = available_parallelism

#[test]
fn planned_kernel_is_bit_exact_with_forward_and_flat_gather() {
    let mut rng = Rng::seed_from_u64(0xC1A0);
    for dims in DIMS {
        let mlp = random_mlp(&mut rng, dims);
        let in_dim = mlp.input_dim();
        let out_dim = mlp.output_dim();
        let mut flat_scratch = BatchScratch::default();
        for &batch in &BATCHES {
            let xs: Vec<f32> =
                (0..batch * in_dim).map(|_| rng.gen_range_f32(0.0, 1.0)).collect();
            for kind in MultiplierKind::ALL {
                let model = MultiplierModel::new(kind);
                // reference 1: the old flat-gather batched kernel
                let flat = mlp.forward_batch_with(&xs, batch, &model, &mut flat_scratch);
                for &threads in &THREADS {
                    let plan = mlp.plan(threads);
                    let mut scratch = PlanScratch::default();
                    let got = plan.forward_batch_with(&xs, batch, &model, &mut scratch);
                    assert_eq!(
                        got, flat,
                        "planned != flat: dims {dims:?} batch {batch} {kind} t{threads}"
                    );
                    // reference 2: the per-sample forward, row by row
                    for b in 0..batch {
                        let want = mlp.forward(&xs[b * in_dim..(b + 1) * in_dim], &model);
                        assert_eq!(
                            &got[b * out_dim..(b + 1) * out_dim],
                            &want[..],
                            "planned != forward: dims {dims:?} {kind} t{threads} row {b}"
                        );
                    }
                }
            }
        }
    }
}

#[test]
fn plan_scratch_reuse_is_stable_across_varying_batches() {
    // One plan + one scratch driven through growing and shrinking
    // batches — the slot/strip buffers must not leak state between runs.
    let mut rng = Rng::seed_from_u64(77);
    let mlp = random_mlp(&mut rng, &[12, 9, 4]);
    let model = MultiplierModel::new(MultiplierKind::Approx2);
    let plan = mlp.plan(3);
    let mut scratch = PlanScratch::default();
    for &batch in &[5usize, 1, 8, 2, 0, 6] {
        let xs: Vec<f32> = (0..batch * 12).map(|_| rng.gen_range_f32(0.0, 1.0)).collect();
        let got = plan.forward_batch_with(&xs, batch, &model, &mut scratch);
        for b in 0..batch {
            let want = mlp.forward(&xs[b * 12..(b + 1) * 12], &model);
            assert_eq!(&got[b * 4..(b + 1) * 4], &want[..], "batch {batch} row {b}");
        }
    }
}

#[test]
fn native_backend_is_bit_exact_for_all_thread_counts() {
    // Same property through the serving-stack entry point: the spec's
    // threads knob must never change the numerics.
    let mut rng = Rng::seed_from_u64(4242);
    let mlp = random_mlp(&mut rng, &[16, 11, 6]);
    let batch = 9;
    let xs: Vec<f32> = (0..batch * 16).map(|_| rng.gen_range_f32(0.0, 1.0)).collect();
    for kind in [MultiplierKind::Ideal, MultiplierKind::Approx, MultiplierKind::DncOpt] {
        let model = MultiplierModel::new(kind);
        for threads in THREADS {
            let gemm = GemmOptions::with_threads(threads);
            let spec = BackendSpec::Native { mlp: mlp.clone(), kind, gemm };
            let mut backend = spec.build().unwrap();
            let out = backend.run_batch(&xs, batch, 16).unwrap();
            for b in 0..batch {
                let want = mlp.forward(&xs[b * 16..(b + 1) * 16], &model);
                assert_eq!(
                    &out.logits[b * 6..(b + 1) * 6],
                    &want[..],
                    "{kind} threads {threads} row {b}"
                );
            }
        }
    }
}

/// The full execution matrix: every strip-kernel knob × tiling mode ×
/// thread count must be bit-identical to the per-sample forward — and
/// therefore to each other. `Auto` resolves to the host's dispatched
/// SIMD kernel when one exists (AVX2 on x86_64, NEON on aarch64) and to
/// SWAR elsewhere, so the sweep exercises the SIMD path wherever the
/// hardware has one while staying portable.
#[test]
fn kernel_tiling_thread_matrix_is_bit_identical() {
    let mut rng = Rng::seed_from_u64(0x51D);
    for dims in [&[64usize, 32, 10][..], &[5, 4, 3], &[33, 17]] {
        let mlp = random_mlp(&mut rng, dims);
        let in_dim = mlp.input_dim();
        for &batch in &[0usize, 1, 7] {
            let xs: Vec<f32> =
                (0..batch * in_dim).map(|_| rng.gen_range_f32(0.0, 1.0)).collect();
            for kind in [MultiplierKind::Ideal, MultiplierKind::DncOpt, MultiplierKind::Approx] {
                let model = MultiplierModel::new(kind);
                let want: Vec<f32> = (0..batch)
                    .flat_map(|b| mlp.forward(&xs[b * in_dim..(b + 1) * in_dim], &model))
                    .collect();
                for simd in [GemmSimd::Scalar, GemmSimd::Swar, GemmSimd::Auto] {
                    for partition in GemmPartition::ALL {
                        for threads in THREADS {
                            let opts = GemmOptions { threads, simd, partition };
                            let plan = mlp.plan_with(opts);
                            let mut scratch = PlanScratch::default();
                            let got = plan.forward_batch_with(&xs, batch, &model, &mut scratch);
                            assert_eq!(
                                got,
                                want,
                                "dims {dims:?} batch {batch} {kind} {}/{}/t{threads}",
                                simd.slug(),
                                partition.slug()
                            );
                        }
                    }
                }
            }
        }
    }
}

#[test]
fn thread_cap_exceeding_batch_is_harmless() {
    let mut rng = Rng::seed_from_u64(9);
    let mlp = random_mlp(&mut rng, &[6, 5]);
    let model = MultiplierModel::new(MultiplierKind::Dnc);
    let plan = mlp.plan(64); // far more threads than rows
    let xs: Vec<f32> = (0..3 * 6).map(|_| rng.gen_range_f32(0.0, 1.0)).collect();
    let got = plan.forward_batch(&xs, 3, &model);
    for b in 0..3 {
        let want = mlp.forward(&xs[b * 6..(b + 1) * 6], &model);
        assert_eq!(&got[b * 5..(b + 1) * 5], &want[..], "row {b}");
    }
}

#[test]
fn degenerate_single_mac_layer_plans_and_runs() {
    // 1×1: one weight code, one bucket occupied, fifteen empty.
    let l = QuantLinear::from_float(&[vec![0.4]], vec![0.1], 1.0, false);
    let mlp = QuantMlp::new(vec![l]);
    let plan = mlp.plan(2);
    let model = MultiplierModel::new(MultiplierKind::Traditional);
    let got = plan.forward_batch(&[0.7, 0.2], 2, &model);
    assert_eq!(got[0..1], mlp.forward(&[0.7], &model)[..]);
    assert_eq!(got[1..2], mlp.forward(&[0.2], &model)[..]);
}
