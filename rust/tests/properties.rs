//! Property-based tests over the core invariants (DESIGN.md §6), using
//! the in-tree `util::check` harness (proptest is unavailable offline).

use luna_cim::cells::{tsmc65_library, CellKind};
use luna_cim::config::Config;
use luna_cim::coordinator::batcher::Batcher;
use luna_cim::coordinator::request::InferenceRequest;
use luna_cim::coordinator::tiler::{Tiler, UnitCosts};
use luna_cim::logic::{from_bits, to_bits, EventSim, Stepper};
use luna_cim::multiplier::{generic, MultiplierKind, MultiplierModel};
use luna_cim::nn::{DigitsDataset, QuantLinear, QuantMlp, Quantizer};
use luna_cim::prop_assert;
use luna_cim::util::check::check;
use luna_cim::util::pool::stats as pool_stats;
use luna_cim::util::ClassPool;
use std::time::Duration;

// ---------------------------------------------------------------------------
// multiplier invariants
// ---------------------------------------------------------------------------

#[test]
fn prop_exact_kinds_equal_ideal_product() {
    check("exact kinds == w*y", 300, |rng| {
        let (w, y) = (rng.gen_u4(), rng.gen_u4());
        for kind in [
            MultiplierKind::Traditional,
            MultiplierKind::Dnc,
            MultiplierKind::DncOpt,
            MultiplierKind::ArrayMult,
        ] {
            prop_assert!(kind.value(w, y) == w * y, "{kind} w={w} y={y}");
        }
        Ok(())
    });
}

#[test]
fn prop_approx_errors_within_paper_ranges() {
    check("approx error ranges", 300, |rng| {
        let (w, y) = (rng.gen_u4(), rng.gen_u4());
        let e1 = MultiplierKind::Approx.error(w, y);
        let e2 = MultiplierKind::Approx2.error(w, y);
        prop_assert!((0..=45).contains(&e1), "approx err {e1}");
        prop_assert!((-15..=30).contains(&e2), "approx2 err {e2}");
        Ok(())
    });
}

#[test]
fn prop_generic_netlist_exact_for_random_even_widths() {
    check("generic D&C == product", 40, |rng| {
        let n = [4u32, 8, 16][rng.gen_below(3) as usize];
        let netlist = generic::netlist(n);
        let mut st = Stepper::new(&netlist);
        let w = rng.gen_below(1 << n);
        st.program(&generic::program_image(n, w));
        for _ in 0..4 {
            let y = rng.gen_below(1 << n);
            let res = st.step(&netlist, &to_bits(y, n as usize));
            prop_assert!(from_bits(&res.outputs) == w * y, "n={n} w={w} y={y}");
        }
        Ok(())
    });
}

#[test]
fn prop_event_sim_agrees_with_stepper_steady_state() {
    // The timing simulator and the zero-delay evaluator must agree on
    // final values for every configuration and stimulus.
    check("event sim == stepper", 60, |rng| {
        let kind = MultiplierKind::PAPER_CONFIGS[rng.gen_below(5) as usize];
        let netlist = kind.netlist().unwrap();
        let w = rng.gen_u4();
        let image = kind.program_image(w).unwrap();
        let mut sim = EventSim::new(&netlist);
        let mut st = Stepper::new(&netlist);
        sim.program(&image);
        st.program(&image);
        for _ in 0..6 {
            let y = rng.gen_u4();
            sim.apply(&to_bits(y as u64, 4));
            let out_nets = netlist.output_nets();
            let sim_val = sim.bus_value(&out_nets);
            let step_val = from_bits(&st.step(&netlist, &to_bits(y as u64, 4)).outputs);
            prop_assert!(sim_val == step_val, "{kind} w={w} y={y}: {sim_val} vs {step_val}");
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// batcher invariants
// ---------------------------------------------------------------------------

#[test]
fn prop_batcher_never_exceeds_max_and_preserves_order() {
    check("batcher size & order", 80, |rng| {
        let max_batch = 1 + rng.gen_below(8) as usize;
        let mut b = Batcher::new(max_batch, Duration::from_secs(3600), 64.max(max_batch));
        let n = rng.gen_below(40) as usize;
        let mut emitted: Vec<u64> = Vec::new();
        for id in 0..n as u64 {
            if let Ok(Some(batch)) = b.push(InferenceRequest::new(id, vec![0.0])) {
                prop_assert!(batch.requests.len() <= max_batch, "oversized batch");
                prop_assert!(batch.padded_to == max_batch, "bad padding target");
                emitted.extend(batch.requests.iter().map(|r| r.id));
            }
        }
        for batch in b.flush_all() {
            prop_assert!(batch.requests.len() <= max_batch, "oversized flush batch");
            emitted.extend(batch.requests.iter().map(|r| r.id));
        }
        let expect: Vec<u64> = (0..n as u64).collect();
        prop_assert!(emitted == expect, "requests lost or reordered: {emitted:?}");
        Ok(())
    });
}

#[test]
fn prop_batcher_backpressure_never_drops_silently() {
    check("batcher backpressure", 50, |rng| {
        let depth = 2 + rng.gen_below(6) as usize;
        let mut b = Batcher::new(depth, Duration::from_secs(3600), depth);
        let mut accepted = 0usize;
        let mut rejected = 0usize;
        let mut emitted = 0usize;
        for id in 0..(depth as u64 * 3) {
            match b.push(InferenceRequest::new(id, vec![0.0])) {
                Ok(Some(batch)) => {
                    accepted += 1;
                    emitted += batch.requests.len();
                }
                Ok(None) => accepted += 1,
                Err(_) => rejected += 1,
            }
        }
        emitted += b.flush_all().iter().map(|x| x.requests.len()).sum::<usize>();
        prop_assert!(
            emitted == accepted,
            "accepted {accepted} != emitted {emitted} (rejected {rejected})"
        );
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// buffer-pool invariants
// ---------------------------------------------------------------------------

#[test]
fn prop_pool_class_boundaries_at_powers_of_two_and_stats_monotone() {
    // Class k's smallest stored buffer is 2^k and its largest routed
    // request is exactly 2^k — so a power-of-two request must recycle a
    // same-sized buffer, while one element more must route to the next
    // class. Stats are process-global (parallel tests also bump them),
    // so only monotone lower bounds are asserted.
    check("pool class boundaries", 60, |rng| {
        let pool: ClassPool<u64> = ClassPool::new();
        let k = 1 + rng.gen_below(16) as usize;
        let exact = 1usize << k;
        let before = pool_stats();

        let v1 = pool.get(exact);
        prop_assert!(v1.capacity() >= exact, "k={k}: under-capacity get");
        let ptr1 = v1.as_ptr();
        pool.put(v1);

        let v2 = pool.get(exact);
        prop_assert!(
            v2.as_ptr() == ptr1,
            "k={k}: exact power-of-two request must hit its own class"
        );

        // one past the boundary routes to class k+1: fresh buffer, big
        // enough, and not the one class k still considers its own size
        let v3 = pool.get(exact + 1);
        prop_assert!(v3.capacity() >= exact + 1, "k={k}: boundary+1 under-capacity");
        prop_assert!(v3.as_ptr() != ptr1, "k={k}: boundary+1 must not reuse class k's buffer");
        pool.put(v2);
        pool.put(v3);

        let after = pool_stats();
        prop_assert!(after.hits >= before.hits + 1, "recycle must register as a hit");
        prop_assert!(after.misses >= before.misses + 2, "two fresh classes must miss");
        prop_assert!(after.recycled >= before.recycled + 3, "three puts must recycle");
        let r = after.hit_rate();
        prop_assert!((0.0..=1.0).contains(&r), "hit rate {r} out of range");
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// tiler / fabric invariants
// ---------------------------------------------------------------------------

fn costs() -> UnitCosts {
    UnitCosts::measure(MultiplierKind::DncOpt, &tsmc65_library())
}

#[test]
fn prop_tiler_covers_every_mac_exactly_once() {
    let c = costs();
    check("tiler coverage", 30, |rng| {
        let units = 1 + rng.gen_below(64) as usize;
        let batch = 1 + rng.gen_below(8) as usize;
        let mlp = QuantMlp::random_for_study(rng.next_u64());
        let mut t = Tiler::new(units, 1, c);
        let s = t.schedule(&mlp, batch);
        prop_assert!(s.total_macs == mlp.macs() * batch as u64, "mac coverage");
        for l in &s.layers {
            prop_assert!(
                l.programs + l.stationary_hits == l.elements as u64,
                "programming accounting"
            );
            prop_assert!(
                l.cycles as u128 * units as u128 >= l.macs as u128,
                "cycles x units must cover macs"
            );
        }
        Ok(())
    });
}

#[test]
fn prop_tiler_energy_is_additive_and_monotone_in_batch() {
    let c = costs();
    check("tiler energy monotone", 20, |rng| {
        let mlp = QuantMlp::random_for_study(rng.next_u64());
        let mut t1 = Tiler::new(32, 1, c);
        let mut t2 = Tiler::new(32, 1, c);
        let b = 1 + rng.gen_below(4) as usize;
        let e_small = t1.schedule(&mlp, b).total_energy_fj;
        let e_big = t2.schedule(&mlp, b + 1).total_energy_fj;
        prop_assert!(e_big > e_small, "more batch => more energy");
        let sched = {
            let mut t = Tiler::new(32, 1, c);
            t.schedule(&mlp, b)
        };
        let layers_sum: f64 = sched.layers.iter().map(|l| l.energy_fj).sum();
        prop_assert!((layers_sum - sched.total_energy_fj).abs() < 1e-6, "energy additivity");
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// nn invariants
// ---------------------------------------------------------------------------

#[test]
fn prop_quantizer_roundtrip_error_bounded() {
    check("quantizer roundtrip", 200, |rng| {
        let max_abs = 0.05 + rng.gen_f64() as f32 * 8.0;
        let q = Quantizer::for_activations(max_abs);
        let x = rng.gen_f64() as f32 * max_abs;
        let err = (q.dequantize(q.quantize(x)) - x).abs();
        prop_assert!(err <= q.scale / 2.0 + 1e-5, "x={x} err={err}");
        Ok(())
    });
}

#[test]
fn prop_mlp_text_roundtrip_is_identity() {
    check("weights text roundtrip", 20, |rng| {
        let mlp = QuantMlp::random_for_study(rng.next_u64());
        let back = QuantMlp::from_text(&mlp.to_text()).map_err(|e| e.to_string())?;
        let x: Vec<f32> = (0..16).map(|_| rng.gen_f64() as f32).collect();
        let m = MultiplierModel::new(MultiplierKind::Approx2);
        prop_assert!(mlp.forward(&x, &m) == back.forward(&x, &m), "outputs changed");
        Ok(())
    });
}

#[test]
fn prop_dataset_binary_roundtrip() {
    check("dataset binary roundtrip", 15, |rng| {
        let d = DigitsDataset::generate(1 + rng.gen_below(4) as usize, rng.next_u64());
        let back = DigitsDataset::from_binary(&d.to_binary()).map_err(|e| e.to_string())?;
        prop_assert!(back.len() == d.len(), "length changed");
        for (a, b) in d.samples.iter().zip(back.samples.iter()) {
            prop_assert!(a.label == b.label && a.pixels == b.pixels, "sample changed");
        }
        Ok(())
    });
}

#[test]
fn prop_exact_lut_layer_matches_integer_reference() {
    check("quant layer vs integer reference", 40, |rng| {
        let in_dim = 1 + rng.gen_below(24) as usize;
        let out_dim = 1 + rng.gen_below(12) as usize;
        let w: Vec<Vec<f32>> = (0..out_dim)
            .map(|_| (0..in_dim).map(|_| rng.gen_range_f32(-0.5, 0.5)).collect())
            .collect();
        let bias = vec![0.0f32; out_dim];
        let layer = QuantLinear::from_float(&w, bias, 1.0, false);
        let xq: Vec<u8> = (0..in_dim).map(|_| rng.gen_u4()).collect();
        let acc = layer.accumulate(&xq, &MultiplierModel::new(MultiplierKind::DncOpt));
        // independent integer reference
        for o in 0..out_dim {
            let row = &layer.wq[o * in_dim..(o + 1) * in_dim];
            let want: i32 =
                row.iter().zip(&xq).map(|(&wc, &xc)| (wc as i32 - 8) * xc as i32).sum();
            prop_assert!(acc[o] == want, "o={o}: {} vs {want}", acc[o]);
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// config invariants
// ---------------------------------------------------------------------------

#[test]
fn prop_config_text_roundtrip() {
    check("config roundtrip", 30, |rng| {
        let mut cfg = Config::default();
        cfg.batcher.max_batch = 1 + rng.gen_below(32) as usize;
        cfg.batcher.queue_depth = cfg.batcher.max_batch + rng.gen_below(64) as usize;
        cfg.workers.count = 1 + rng.gen_below(8) as usize;
        cfg.banks.count = 1 + rng.gen_below(64) as usize;
        cfg.banks.units_per_bank = 1 + rng.gen_below(4) as usize;
        cfg.multiplier =
            MultiplierKind::ALL[rng.gen_below(MultiplierKind::ALL.len() as u64) as usize];
        let back = Config::from_text(&cfg.to_text()).map_err(|e| e.to_string())?;
        prop_assert!(back == cfg, "roundtrip changed config");
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// energy accounting invariants
// ---------------------------------------------------------------------------

#[test]
fn prop_bank_ledger_is_additive() {
    let lib = tsmc65_library();
    check("ledger additivity", 20, |rng| {
        let mut bank = luna_cim::luna::LunaBank::new(MultiplierKind::DncOpt, 2);
        let ops = 1 + rng.gen_below(20);
        bank.program_unit(&lib, 0, rng.gen_u4());
        bank.program_unit(&lib, 1, rng.gen_u4());
        let after_prog = bank.ledger().total_fj();
        for _ in 0..ops {
            let _ = bank.mac(&lib, 0, rng.gen_u4());
        }
        let total = bank.ledger().total_fj();
        prop_assert!(total >= after_prog, "energy decreased");
        let unit_mux = bank.units[0].ledger().breakdown().get(CellKind::Mux2);
        let merged_mux = bank.ledger().breakdown().get(CellKind::Mux2);
        prop_assert!(
            (merged_mux - unit_mux).abs() < 1e-9,
            "merged mux energy {merged_mux} != unit {unit_mux}"
        );
        Ok(())
    });
}
