//! Shared helpers for integration suites that drive the serving stack
//! over synthesized artifacts (no `make artifacts`, no HLO files).

use luna_cim::nn::{DigitsDataset, QuantMlp};
use luna_cim::runtime::ArtifactStore;

/// Write a self-contained artifact directory for the given digits-shaped
/// model: the native and calibrated backends need manifest + weights +
/// testset only (one shared writer — see `ArtifactStore::write_synthetic`).
pub fn synth_artifacts(tag: &str, mlp: &QuantMlp, batch: usize) -> (ArtifactStore, DigitsDataset) {
    let dir = luna_cim::util::test_dir(tag);
    let store = ArtifactStore::new(&dir);
    let testset = DigitsDataset::generate(4, 99);
    store.write_synthetic(mlp, &testset, batch).unwrap();
    (store, testset)
}
