//! Shared helpers for integration suites that drive the serving stack
//! over synthesized artifacts (no `make artifacts`, no HLO files).

use luna_cim::nn::{DigitsDataset, QuantMlp};
use luna_cim::runtime::{ArtifactStore, ModelMeta};

/// Write a self-contained artifact directory for the given digits-shaped
/// model: the native and calibrated backends need manifest + weights +
/// testset only.
pub fn synth_artifacts(tag: &str, mlp: &QuantMlp, batch: usize) -> (ArtifactStore, DigitsDataset) {
    let dir = luna_cim::util::test_dir(tag);
    let store = ArtifactStore::new(&dir);
    let testset = DigitsDataset::generate(4, 99);
    let meta = ModelMeta {
        dims: vec![64, 32, 10],
        batch,
        variants: vec!["ideal".into()],
        train_accuracy: 0.0,
        test_samples: testset.len(),
    };
    std::fs::write(store.manifest_path(), meta.to_text()).unwrap();
    std::fs::write(store.weights_path(), mlp.to_text()).unwrap();
    std::fs::write(store.testset_path(), testset.to_binary()).unwrap();
    (store, testset)
}
