//! Native-backend integration tests.
//!
//! Unlike `coordinator_integration.rs` (which needs `make artifacts`),
//! these synthesize a complete artifact directory — manifest, weights,
//! test set, **no HLO files** — and drive the full serving stack
//! (batcher → router → native workers → completion pool) through it,
//! proving the coordinator serves traffic with zero external
//! dependencies and stays bit-exact with the functional model.

mod common;

use common::synth_artifacts;
use luna_cim::config::{BackendKind, Config};
use luna_cim::coordinator::CoordinatorServer;
use luna_cim::engine::{BackendSpec, ExecBackend};
use luna_cim::multiplier::{MultiplierKind, MultiplierModel};
use luna_cim::nn::{GemmOptions, QuantMlp};
use luna_cim::util::Rng;

#[test]
fn batched_native_gemm_is_bit_exact_for_every_kind() {
    // The headline equivalence: forward_batch == per-sample forward,
    // exhaustively over every multiplier configuration, on the
    // digits-shaped model with a padded (partially zero) batch.
    let mlp = QuantMlp::random_digits(23);
    let mut rng = Rng::seed_from_u64(77);
    let batch = 8;
    let mut xs: Vec<f32> = (0..batch * 64).map(|_| rng.gen_range_f32(0.0, 1.0)).collect();
    // last two rows zero, like batcher padding
    for v in xs.iter_mut().skip(6 * 64) {
        *v = 0.0;
    }
    for kind in MultiplierKind::ALL {
        let model = MultiplierModel::new(kind);
        let got = mlp.forward_batch(&xs, batch, &model);
        for b in 0..batch {
            let want = mlp.forward(&xs[b * 64..(b + 1) * 64], &model);
            assert_eq!(&got[b * 10..(b + 1) * 10], &want[..], "{kind} row {b}");
        }
    }
}

#[test]
fn native_backend_through_spec_matches_forward_batch() {
    let mlp = QuantMlp::random_digits(31);
    let gemm = GemmOptions::with_threads(2);
    let spec = BackendSpec::Native { mlp: mlp.clone(), kind: MultiplierKind::Approx, gemm };
    let mut backend = spec.build().unwrap();
    let model = MultiplierModel::new(MultiplierKind::Approx);
    let xs = vec![0.5f32; 3 * 64];
    let out = backend.run_batch(&xs, 3, 64).unwrap();
    assert_eq!(out.logits.len(), 3 * 10, "batch x out_dim logits");
    assert!(out.cost.is_none(), "native backend has no timing model");
    assert_eq!(out.logits, mlp.forward_batch(&xs, 3, &model));
}

#[test]
fn native_server_completes_multi_batch_run_without_pjrt_artifacts() {
    let mlp = QuantMlp::random_digits(47);
    let (store, testset) = synth_artifacts("native-e2e", &mlp, 8);
    // assert the premise: no PJRT/HLO artifacts exist in the directory
    let hlo_files: Vec<_> = std::fs::read_dir(store.root())
        .unwrap()
        .filter_map(|e| e.ok())
        .filter(|e| e.file_name().to_string_lossy().contains("hlo"))
        .collect();
    assert!(hlo_files.is_empty(), "test dir must hold no HLO artifacts");

    let mut cfg = Config::default();
    cfg.artifacts_dir = store.root().display().to_string();
    cfg.backend = BackendKind::Native;
    cfg.multiplier = MultiplierKind::DncOpt;
    let (server, handle) = CoordinatorServer::start(cfg).unwrap();

    let model = MultiplierModel::new(MultiplierKind::DncOpt);
    let n = 40.min(testset.len()); // 5 full batches of 8
    let mut threads = Vec::new();
    for t in 0..4 {
        let handle = handle.clone();
        let samples: Vec<Vec<f32>> = testset.samples[t * n / 4..(t + 1) * n / 4]
            .iter()
            .map(|s| s.pixels.clone())
            .collect();
        threads.push(std::thread::spawn(move || {
            samples
                .into_iter()
                .map(|px| {
                    let resp = handle.submit(px.clone()).expect("native serve");
                    (px, resp)
                })
                .collect::<Vec<_>>()
        }));
    }
    let mut total = 0usize;
    for t in threads {
        for (px, resp) in t.join().unwrap() {
            total += 1;
            assert_eq!(resp.logits.len(), 10);
            // native execution is bit-exact with the functional model
            assert_eq!(resp.logits, mlp.forward(&px, &model));
            assert_eq!(resp.label, mlp.classify(&px, &model));
            assert!(resp.sim_energy_fj > 0.0);
        }
    }
    assert_eq!(total, n);
    let snap = server.metrics().snapshot();
    assert_eq!(snap.requests, n as u64);
    assert!(snap.batches >= (n / 8) as u64, "multi-batch run expected");
    assert_eq!(snap.failed_batches, 0);
    server.shutdown();
}

#[test]
fn native_and_variant_servers_disagree_on_approx_numerics() {
    // Sanity that the backend threads the multiplier kind through: an
    // Approx2 server must produce Approx2 logits, not ideal ones.
    let mlp = QuantMlp::random_digits(53);
    let (store, testset) = synth_artifacts("native-approx2", &mlp, 8);
    let mut cfg = Config::default();
    cfg.artifacts_dir = store.root().display().to_string();
    cfg.multiplier = MultiplierKind::Approx2;
    let (server, handle) = CoordinatorServer::start(cfg).unwrap();
    let approx2 = MultiplierModel::new(MultiplierKind::Approx2);
    for s in testset.samples.iter().take(8) {
        let resp = handle.submit(s.pixels.clone()).unwrap();
        assert_eq!(resp.logits, mlp.forward(&s.pixels, &approx2));
    }
    server.shutdown();
}
