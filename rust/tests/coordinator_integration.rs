//! Integration tests of the serving coordinator over the real artifacts
//! (skipped gracefully when `make artifacts` has not run).

use luna_cim::config::Config;
use luna_cim::coordinator::CoordinatorServer;
use luna_cim::multiplier::{MultiplierKind, MultiplierModel};
use luna_cim::runtime::ArtifactStore;

fn config_or_skip() -> Option<Config> {
    let cfg = Config::default();
    if ArtifactStore::new(&cfg.artifacts_dir).exists() {
        Some(cfg)
    } else {
        eprintln!("skipping: artifacts/ missing (run `make artifacts`)");
        None
    }
}

#[test]
fn serves_correct_labels_under_concurrent_load() {
    let Some(cfg) = config_or_skip() else { return };
    let store = ArtifactStore::new(&cfg.artifacts_dir);
    let testset = store.load_testset().unwrap();
    let mlp = store.load_mlp().unwrap();
    let ideal = MultiplierModel::new(MultiplierKind::Ideal);

    let (server, handle) = CoordinatorServer::start(cfg).unwrap();
    let n = 48.min(testset.len());
    let mut threads = Vec::new();
    for t in 0..6 {
        let handle = handle.clone();
        let samples: Vec<(Vec<f32>, usize)> = testset.samples
            [t * n / 6..(t + 1) * n / 6]
            .iter()
            .map(|s| (s.pixels.clone(), s.label))
            .collect();
        threads.push(std::thread::spawn(move || {
            let mut results = Vec::new();
            for (px, label) in samples {
                let resp = handle.submit(px.clone()).expect("submit");
                results.push((px, label, resp));
            }
            results
        }));
    }
    let mut total = 0usize;
    let mut functional_agree = 0usize;
    for t in threads {
        for (px, _label, resp) in t.join().unwrap() {
            total += 1;
            assert_eq!(resp.logits.len(), 10);
            assert!(resp.sim_energy_fj > 0.0);
            assert!(resp.sim_latency_ps > 0);
            // served label must match the bit-accurate functional model
            if resp.label == mlp.classify(&px, &ideal) {
                functional_agree += 1;
            }
        }
    }
    assert_eq!(total, n / 6 * 6);
    // float rounding-mode ties can flip an occasional argmax
    assert!(functional_agree * 10 >= total * 9, "{functional_agree}/{total}");

    let snap = server.metrics().snapshot();
    assert_eq!(snap.requests, total as u64);
    assert!(snap.batches >= (total / 8) as u64);
    assert!(snap.throughput_rps > 0.0);
    server.shutdown();
}

#[test]
fn variant_server_uses_variant_numerics() {
    let Some(mut cfg) = config_or_skip() else { return };
    cfg.multiplier = MultiplierKind::Approx;
    let store = ArtifactStore::new(&cfg.artifacts_dir);
    let testset = store.load_testset().unwrap();
    let mlp = store.load_mlp().unwrap();

    let (server, handle) = CoordinatorServer::start(cfg).unwrap();
    let approx = MultiplierModel::new(MultiplierKind::Approx);
    let mut agree = 0usize;
    let n = 16;
    for s in testset.samples.iter().take(n) {
        let resp = handle.submit(s.pixels.clone()).unwrap();
        if resp.label == mlp.classify(&s.pixels, &approx) {
            agree += 1;
        }
    }
    assert!(agree * 10 >= n * 9, "approx-served labels diverge: {agree}/{n}");
    server.shutdown();
}

#[test]
fn mismatched_batch_config_is_rejected() {
    let Some(mut cfg) = config_or_skip() else { return };
    cfg.batcher.max_batch = 5; // artifacts were lowered with batch 8
    assert!(CoordinatorServer::start(cfg).is_err());
}

#[test]
fn wrong_input_dim_is_rejected_per_request() {
    let Some(cfg) = config_or_skip() else { return };
    let (server, handle) = CoordinatorServer::start(cfg).unwrap();
    assert!(handle.submit(vec![0.0; 3]).is_err());
    server.shutdown();
}

#[test]
fn weight_stationary_energy_amortizes_across_batches() {
    let Some(cfg) = config_or_skip() else { return };
    let store = ArtifactStore::new(&cfg.artifacts_dir);
    let testset = store.load_testset().unwrap();
    let (server, handle) = CoordinatorServer::start(cfg).unwrap();
    let px = testset.samples[0].pixels.clone();
    let first = handle.submit(px.clone()).unwrap();
    // drive enough requests to fill several batches
    let mut last = first.clone();
    for _ in 0..24 {
        last = handle.submit(px.clone()).unwrap();
    }
    // later batches reprogram nothing, so per-request energy drops
    assert!(
        last.sim_energy_fj < first.sim_energy_fj,
        "stationary reuse should amortize: first {} later {}",
        first.sim_energy_fj,
        last.sim_energy_fj
    );
    server.shutdown();
}
