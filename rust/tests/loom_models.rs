//! Cross-module loom models: the coordinator-facing concurrency
//! protocols built *on top of* [`luna_cim::util::queue`] (whose own
//! close/drain models live next to its source as `#[cfg(loom)]` unit
//! models).
//!
//! Run with:
//!
//! ```text
//! RUSTFLAGS="--cfg loom" LOOM_MAX_PREEMPTIONS=3 \
//!     cargo test --release --test loom_models
//! ```
//!
//! Each `loom::model` body executes once per explored interleaving, so
//! every primitive it touches must be created inside the closure. The
//! preemption bound keeps CI wall-time sane; loom's own evidence is
//! that 2–3 preemptions catch practically all real bugs.

#![cfg(loom)]

use luna_cim::coordinator::worker::{ReplyTicket, WorkerReply};
use luna_cim::coordinator::AdmissionGate;
use luna_cim::engine::BatchOutput;
use luna_cim::util::queue;
use luna_cim::util::sync::Arc;

/// A ticket dropped without sending (worker panic, discarded job) must
/// deliver the "worker dropped reply" error to the completion queue —
/// exactly once, in every interleaving of the drop vs the receiver.
#[test]
fn dropped_ticket_delivers_worker_death_exactly_once() {
    loom::model(|| {
        let (ctx, crx) = queue::channel::<WorkerReply>();
        let t = loom::thread::spawn(move || {
            drop(ReplyTicket::new(ctx, 7));
        });
        let reply = crx.recv().expect("drop guard always delivers");
        assert_eq!(reply.batch_id, 7);
        let err = reply.result.expect_err("guard reports worker death");
        assert!(format!("{err:#}").contains("worker dropped reply"));
        t.join().unwrap();
        assert!(crx.recv().is_none(), "exactly once: nothing after the guard reply");
    });
}

/// An explicitly sent ticket disarms its guard: the success reply is
/// the only reply, no matter how the sender thread interleaves with
/// the completion-side receiver.
#[test]
fn sent_ticket_disarms_its_drop_guard() {
    loom::model(|| {
        let (ctx, crx) = queue::channel::<WorkerReply>();
        let t = loom::thread::spawn(move || {
            ReplyTicket::new(ctx, 8).send(Ok(BatchOutput::plain(vec![1.0f32])), 0);
        });
        let reply = crx.recv().expect("explicit reply delivered");
        assert_eq!(reply.batch_id, 8);
        assert!(reply.result.is_ok());
        t.join().unwrap();
        assert!(crx.recv().is_none(), "no second delivery from the disarmed guard");
    });
}

/// The teardown path the queue's drain-outside-the-lock exists for: a
/// job queue dies with a ticket-bearing job still buffered, and the
/// drain must fire the guard — a *send on another queue from inside a
/// value's destructor* — without deadlocking or losing the reply.
#[test]
fn queue_drain_fires_ticket_guards_onto_completion_queue() {
    loom::model(|| {
        let (jobs_tx, jobs_rx) = queue::channel::<ReplyTicket>();
        let (ctx, crx) = queue::channel::<WorkerReply>();
        jobs_tx.send(ReplyTicket::new(ctx, 9)).unwrap();
        // worker death: the only receiver drops concurrently with the
        // producer side going away
        let t = loom::thread::spawn(move || drop(jobs_rx));
        drop(jobs_tx);
        let reply = crx.recv().expect("drained job's guard delivers");
        assert_eq!(reply.batch_id, 9);
        assert!(reply.result.is_err());
        t.join().unwrap();
        assert!(crx.recv().is_none(), "the drained ticket replies exactly once");
    });
}

/// Concurrent submit/reject/complete against a depth-1 gate: held
/// permits never exceed the bound, rejected admits back out fully, and
/// after every thread finishes the count returns to zero (no leak).
#[test]
fn admission_count_never_exceeds_queue_depth_or_leaks() {
    loom::model(|| {
        let gate = Arc::new(AdmissionGate::new(1));
        // std atomic ledger of *held* permits: helper bookkeeping only,
        // asserted per interleaving, not part of the modeled sync.
        let held = std::sync::Arc::new(std::sync::atomic::AtomicUsize::new(0));
        let mut threads = Vec::new();
        for _ in 0..2 {
            let gate = gate.clone();
            let held = held.clone();
            threads.push(loom::thread::spawn(move || {
                match gate.try_admit() {
                    Ok(()) => {
                        let now = held.fetch_add(1, std::sync::atomic::Ordering::Relaxed) + 1;
                        assert!(now <= 1, "held permits exceeded queue_depth");
                        held.fetch_sub(1, std::sync::atomic::Ordering::Relaxed);
                        gate.release(1); // complete
                    }
                    Err(observed) => {
                        // rejected: the speculative increment was backed
                        // out inside try_admit; the observation is only
                        // a retry hint
                        assert!(observed >= 1);
                    }
                }
            }));
        }
        for t in threads {
            t.join().unwrap();
        }
        assert_eq!(gate.outstanding(), 0, "every admit balanced by exactly one release");
    });
}
