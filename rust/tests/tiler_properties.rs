//! Property tests for `Tiler::schedule` — the scheduler is load-bearing
//! now that `backend calibrated` replays schedules on the serving path,
//! so its invariants are pinned here over randomized (but seeded, hence
//! reproducible) fabric sizes, batch sizes and models.
//!
//! Invariants under test:
//! * every MAC of the model is scheduled: `total_macs == mlp.macs() × batch`;
//! * element conservation per layer: `programs + stationary_hits == elements`;
//! * a second identical batch strictly cheapens (weight-stationary reuse);
//! * cycles are monotonically non-increasing in fabric size.

use luna_cim::cells::tsmc65_library;
use luna_cim::coordinator::tiler::{Tiler, UnitCosts};
use luna_cim::multiplier::MultiplierKind;
use luna_cim::nn::QuantMlp;
use luna_cim::util::Rng;

fn costs() -> UnitCosts {
    UnitCosts::measure_cached(MultiplierKind::DncOpt, &tsmc65_library())
}

fn random_mlp(rng: &mut Rng) -> QuantMlp {
    if rng.gen_bool(0.5) {
        QuantMlp::random_for_study(rng.next_u64()) // 16→12→8: 288 elements
    } else {
        QuantMlp::random_digits(rng.next_u64()) // 64→32→10: 2368 elements
    }
}

fn total_elements(mlp: &QuantMlp) -> u64 {
    mlp.layers.iter().map(|l| l.wq.len() as u64).sum()
}

#[test]
fn every_mac_is_scheduled_and_elements_are_conserved() {
    let costs = costs();
    let mut rng = Rng::seed_from_u64(0x71e3);
    for case in 0..24 {
        let mlp = random_mlp(&mut rng);
        let banks = rng.gen_range_u64(1, 96) as usize;
        let units_per_bank = rng.gen_range_u64(1, 5) as usize;
        let batch = rng.gen_range_u64(1, 17) as usize;
        let mut t = Tiler::new(banks, units_per_bank, costs);
        let s = t.schedule(&mlp, batch);
        let ctx = format!("case {case}: {banks}x{units_per_bank} units, batch {batch}");
        assert_eq!(s.total_macs, mlp.macs() * batch as u64, "{ctx}");
        let units = banks * units_per_bank;
        for l in &s.layers {
            let layer = l.layer;
            assert_eq!(l.programs + l.stationary_hits, l.elements as u64, "{ctx} layer {layer}");
            assert!(l.waves >= 1, "{ctx}");
            // each wave executes one multiply per sample on ≤ units units
            assert!(l.cycles as usize >= l.elements.div_ceil(units) * batch, "{ctx}");
        }
        assert_eq!(s.total_programs + s.total_stationary_hits, total_elements(&mlp), "{ctx}");
        assert_eq!(s.latency_ps, s.total_cycles * costs.cycle_ps, "{ctx}");
        assert!(s.total_energy_fj > 0.0, "{ctx}");
    }
}

#[test]
fn second_identical_batch_strictly_cheapens() {
    // Reprogramming can never *increase* across identical batches (the
    // only writes whose outcome can differ between the passes are each
    // unit's first write, which cost a program from the blank fabric in
    // pass one). A strict decrease is guaranteed whenever some unit is
    // written at most once per pass — i.e. `2 × units > elements` — so
    // fabrics are sampled in that regime; the general non-increase is
    // asserted separately below over unconstrained fabrics.
    let costs = costs();
    let mut rng = Rng::seed_from_u64(0xbea7);
    for case in 0..16 {
        let mlp = random_mlp(&mut rng);
        let elements = total_elements(&mlp);
        let units = rng.gen_range_u64(elements / 2 + 1, 2 * elements) as usize;
        let batch = rng.gen_range_u64(1, 9) as usize;
        let mut t = Tiler::new(units, 1, costs);
        let s1 = t.schedule(&mlp, batch);
        let s2 = t.schedule(&mlp, batch);
        let ctx = format!("case {case}: {units} units, batch {batch}, {elements} elements");
        assert!(s1.total_programs > 0, "{ctx}: blank fabric must program");
        assert!(s2.total_programs < s1.total_programs, "{ctx}");
        assert!(s2.total_stationary_hits > s1.total_stationary_hits, "{ctx}");
        assert!(
            s2.total_energy_fj < s1.total_energy_fj,
            "{ctx}: {} !< {}",
            s2.total_energy_fj,
            s1.total_energy_fj
        );
        // MAC work and latency are batch properties, not fabric-state ones
        assert_eq!(s2.total_macs, s1.total_macs, "{ctx}");
        assert_eq!(s2.latency_ps, s1.latency_ps, "{ctx}");
    }
}

#[test]
fn repeat_batches_never_cost_more_on_any_fabric() {
    let costs = costs();
    let mut rng = Rng::seed_from_u64(0x5eed);
    for case in 0..16 {
        let mlp = random_mlp(&mut rng);
        let units = rng.gen_range_u64(1, 400) as usize;
        let batch = rng.gen_range_u64(1, 9) as usize;
        let mut t = Tiler::new(units, 1, costs);
        let s1 = t.schedule(&mlp, batch);
        let s2 = t.schedule(&mlp, batch);
        let ctx = format!("case {case}: {units} units, batch {batch}");
        assert!(s2.total_programs <= s1.total_programs, "{ctx}");
        assert!(s2.total_energy_fj <= s1.total_energy_fj, "{ctx}");
    }
}

#[test]
fn cycles_are_monotonically_non_increasing_in_fabric_size() {
    let costs = costs();
    let mut rng = Rng::seed_from_u64(0xfab5);
    for case in 0..8 {
        let mlp = random_mlp(&mut rng);
        let batch = rng.gen_range_u64(1, 9) as usize;
        let mut prev_cycles = u64::MAX;
        // strictly growing fabric sizes, fresh fabric each time
        let mut units = rng.gen_range_u64(1, 8) as usize;
        for _ in 0..6 {
            let mut t = Tiler::new(units, 1, costs);
            let s = t.schedule(&mlp, batch);
            assert!(
                s.total_cycles <= prev_cycles,
                "case {case}: {units} units, batch {batch}: cycles grew to {}",
                s.total_cycles
            );
            prev_cycles = s.total_cycles;
            units *= rng.gen_range_u64(2, 5) as usize;
        }
    }
}
