//! The zero-allocation pin: N warm requests through a loopback
//! wire-protocol server must perform **zero** heap allocations end to
//! end — socket read, frame decode, admission, batching, flatten,
//! worker GEMM (plus the tiler schedule replay on `backend
//! calibrated`), reply frame, socket write, and the client's own
//! send/receive loop.
//!
//! A counting global allocator wraps the system allocator; after a
//! generous warmup (pools populated, maps at steady capacity, schedule
//! memo filled, fabric state warm) the allocation counter must not move
//! across hundreds of requests. Any regression — a stray `to_vec`, a
//! fresh batch buffer, a per-send channel node, a per-batch schedule
//! vector — shows up as a precise nonzero delta.
//!
//! The same pin covers warm **two-tenant** traffic: alternating
//! model-tagged requests between the default model and a hot-loaded
//! second tenant must also allocate nothing — a plan-cache hit is one
//! lock, one map lookup and an `Arc` clone, and the model-tagged frame
//! encodes through the same reused scratch.
//!
//! The pin also covers the **persistent GEMM worker pool**
//! (`gemm.threads 2`): pool workers are spawned once at plan compile
//! and parked on condvars between batches, so closed-loop batch-1
//! traffic — which the `auto` partition tiles across per-layer output
//! spans — must wake, accumulate and park without a single allocation.
//!
//! The pin runs with **tracing on**: the default config keeps 1-in-8
//! flight-recorder sampling live, so the zero-delta window proves the
//! recorder's span path (ring cells + Relaxed atomics) and the
//! per-stage histograms allocate nothing — and the test asserts the
//! sampled spans actually landed, so the pin can't be satisfied by a
//! recorder that silently no-ops.
//!
//! This file intentionally holds a single `#[test]`: the counter is
//! process-global, so a concurrently running second test would pollute
//! the measured window.
//!
//! Under ThreadSanitizer (CI exports `LUNA_TSAN=1`) the zero-delta
//! assertion is skipped: TSan interposes on the allocator and its
//! shadow bookkeeping makes the count meaningless there. The run still
//! exercises the full path — the sanitizer job is after races, not
//! allocation counts.

mod common;

use common::synth_artifacts;
use luna_cim::config::{BackendKind, Config};
use luna_cim::coordinator::CoordinatorServer;
use luna_cim::net::{Frame, ModelId, NetClient, NetServer};
use luna_cim::nn::QuantMlp;
use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

/// Counts every allocation event (alloc, alloc_zeroed, realloc) before
/// delegating to the system allocator.
struct CountingAlloc;

static ALLOC_EVENTS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOC_EVENTS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOC_EVENTS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOC_EVENTS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static COUNTER: CountingAlloc = CountingAlloc;

/// Drive `n` closed-loop requests over the wire; panics on any
/// non-Response reply. The loop itself is allocation-free: one reused
/// pixel buffer, pooled frames in and out.
fn drive(client: &mut NetClient, pixels: &[f32], n: usize) {
    for _ in 0..n {
        match client.infer(pixels) {
            Ok(Frame::Response { label, .. }) => assert!((label as usize) < 10),
            Ok(other) => panic!("unexpected reply {other:?}"),
            Err(e) => panic!("infer failed: {e:#}"),
        }
    }
}

/// Stand up one server configuration, warm it, and assert zero
/// allocations across the measured window. `gemm_threads > 1` routes
/// every batch through the persistent worker pool.
fn pin_zero_allocs(backend: BackendKind, shards: usize, gemm_threads: usize, tag: &str) {
    let mlp = QuantMlp::random_digits(97);
    let (store, testset) = synth_artifacts(tag, &mlp, 8);
    let mut cfg = Config::default();
    cfg.artifacts_dir = store.root().display().to_string();
    cfg.backend = backend;
    cfg.batcher.shards = shards;
    cfg.gemm.threads = gemm_threads;
    // short deadline so the closed loop turns around quickly
    cfg.batcher.max_wait_us = 200;
    let (server, handle) = CoordinatorServer::start(cfg).unwrap();
    let net = NetServer::bind(handle.clone(), "127.0.0.1:0", 4).unwrap();
    let mut client = NetClient::connect(net.local_addr()).unwrap();
    let pixels = testset.samples[0].pixels.clone();

    // Warmup: populate every pool class, grow the maps and queue
    // rings to steady capacity, fill the schedule memo and (for
    // calibrated) the weight-stationary fabric + tiler scratch. Two
    // rounds so anything the first round's completions recycle late is
    // re-drawn before measurement.
    drive(&mut client, &pixels, 128);
    drive(&mut client, &pixels, 64);

    let before = ALLOC_EVENTS.load(Ordering::Relaxed);
    drive(&mut client, &pixels, 256);
    let delta = ALLOC_EVENTS.load(Ordering::Relaxed) - before;
    if std::env::var_os("LUNA_TSAN").is_none() {
        assert_eq!(
            delta, 0,
            "warm wire path allocated {delta} times across 256 requests \
             ({tag}, {shards} shard(s)) — the hot path must be allocation-free"
        );
    }

    // sanity: the server actually served everything we sent
    let snap = handle.metrics().snapshot();
    assert_eq!(snap.accepted, 448, "{tag} admission count");
    assert_eq!(snap.rejected, 0);
    assert!(snap.pool.hits > 0, "pooled buffers must be recycling");
    // tracing was live the whole time at the default 1-in-8 sampling
    // and the window still allocated nothing — and the sampled spans
    // really landed in the ring (the recorder is not a silent no-op)
    let spans = handle.recorder().events();
    assert!(!spans.is_empty(), "{tag}: default sampling captured no spans");
    assert!(spans.iter().all(|s| s.trace != 0 && s.dur_us >= 1), "{tag}: malformed span");
    // per-stage histograms: request-granular stages sample once per
    // request, batch-granular ones once per batch (write-back lands
    // moments after the last reply, so it is not pinned here)
    assert_eq!(snap.stage_count[0], 448, "{tag} ingress histogram");
    assert_eq!(snap.stage_count[2], 448, "{tag} queue-wait histogram");
    assert_eq!(snap.stage_count[4], snap.batches, "{tag} gemm histogram");
    net.shutdown();
    server.shutdown();
}

/// Drive `n` requests alternating the default model and `m1`; the
/// two-tenant steady state must be as allocation-free as the
/// single-tenant one.
fn drive_two_models(client: &mut NetClient, m1: ModelId, pixels: &[f32], n: usize) {
    for i in 0..n {
        let model = if i % 2 == 0 { ModelId::DEFAULT } else { m1 };
        match client.infer_model(model, pixels) {
            Ok(Frame::Response { label, .. }) => assert!((label as usize) < 10),
            Ok(other) => panic!("unexpected reply {other:?}"),
            Err(e) => panic!("infer failed: {e:#}"),
        }
    }
}

/// Two resident tenants, alternating traffic: every measured request is
/// a plan-cache hit on one model or the other, and the window must not
/// allocate.
fn pin_zero_allocs_two_models(tag: &str) {
    let mlp_a = QuantMlp::random_digits(97);
    let mlp_b = QuantMlp::random_digits(98);
    let (store, testset) = synth_artifacts(tag, &mlp_a, 8);
    let (store_b, _testset_b) = synth_artifacts("hot-path-tenant-b", &mlp_b, 8);
    let mut cfg = Config::default();
    cfg.artifacts_dir = store.root().display().to_string();
    cfg.batcher.shards = 2;
    cfg.batcher.max_wait_us = 200;
    cfg.serving.models = vec![("m1".to_string(), store_b.root().display().to_string())];
    let (server, handle) = CoordinatorServer::start(cfg).unwrap();
    let net = NetServer::bind(handle.clone(), "127.0.0.1:0", 4).unwrap();
    let mut client = NetClient::connect(net.local_addr()).unwrap();
    let m1 = ModelId::new("m1").unwrap();
    let pixels = testset.samples[0].pixels.clone();

    // warmup: both tenants' plans compiled and resident, every worker's
    // per-model executor built, maps and pools at steady capacity
    drive_two_models(&mut client, m1, &pixels, 128);
    drive_two_models(&mut client, m1, &pixels, 64);

    let before = ALLOC_EVENTS.load(Ordering::Relaxed);
    drive_two_models(&mut client, m1, &pixels, 256);
    let delta = ALLOC_EVENTS.load(Ordering::Relaxed) - before;
    if std::env::var_os("LUNA_TSAN").is_none() {
        assert_eq!(
            delta, 0,
            "warm two-tenant wire path allocated {delta} times across 256 requests \
             ({tag}) — the plan-cache hit path must be allocation-free"
        );
    }
    let snap = handle.metrics().snapshot();
    assert_eq!(snap.accepted, 448, "{tag} admission count");
    assert_eq!(snap.rejected, 0);
    assert!(snap.plan_hits > 0, "two-tenant traffic must hit the plan cache");
    assert_eq!(snap.plan_evictions, 0, "the default budget holds both tenants");
    net.shutdown();
    server.shutdown();
}

#[test]
fn warm_wire_requests_allocate_nothing() {
    for shards in [1usize, 2] {
        pin_zero_allocs(BackendKind::Native, shards, 1, "hot-path-native");
    }
    // the persistent GEMM pool: workers spawned once at plan compile,
    // parked between batches — the closed loop's small batches land on
    // the output-span tiling (`partition auto`), so the wake/accumulate/
    // park cycle itself is inside the measured zero-alloc window
    pin_zero_allocs(BackendKind::Native, 2, 2, "hot-path-native-pool");
    // calibrated adds the per-batch tiler replay; the schedule-buffer
    // arena (Tiler::schedule_cost) keeps it allocation-free too
    pin_zero_allocs(BackendKind::Calibrated, 2, 1, "hot-path-calibrated");
    // and the multi-tenant hit path adds nothing on top
    pin_zero_allocs_two_models("hot-path-two-models");
}
