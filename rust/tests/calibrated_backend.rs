//! Calibrated-backend integration tests (mirroring `native_backend.rs`):
//! synthesize a complete artifact directory — manifest, weights, test
//! set, no HLO files — and drive the full serving stack end-to-end with
//! `backend calibrated`, proving:
//!
//! * replies are bit-exact with `backend native` (the timing model never
//!   touches numerics);
//! * every reply carries a populated simulated cost (`sim_energy_fj`,
//!   `sim_latency_ps`) that matches an offline `Tiler` replay exactly;
//! * the metrics report aggregates and renders the new energy/latency/
//!   stationary-hit lines.

mod common;

use common::synth_artifacts;
use luna_cim::config::{BackendKind, Config};
use luna_cim::coordinator::tiler::{Tiler, UnitCosts};
use luna_cim::coordinator::CoordinatorServer;
use luna_cim::multiplier::{MultiplierKind, MultiplierModel};
use luna_cim::nn::QuantMlp;
use luna_cim::runtime::ArtifactStore;

/// Total weight elements of the digits-shaped model (64·32 + 32·10).
const DIGITS_ELEMS: u64 = 2368;

/// A fresh offline tiler identical to the serving fabric of
/// [`calibrated_cfg`] (2368 units, dnc-opt calibration) — used to replay
/// the schedule stream the server's worker must have produced.
fn replay_tiler() -> Tiler {
    let lib = luna_cim::cells::tsmc65_library();
    Tiler::new(2368, 1, UnitCosts::measure_cached(MultiplierKind::DncOpt, &lib))
}

/// A calibrated config over the synthesized artifacts: one worker (so
/// the weight-stationary fabric sees every batch) and a fabric large
/// enough to hold the whole digits model (592 banks × 4 units = 2368).
fn calibrated_cfg(store: &ArtifactStore) -> Config {
    let mut cfg = Config::default();
    cfg.artifacts_dir = store.root().display().to_string();
    cfg.backend = BackendKind::Calibrated;
    cfg.multiplier = MultiplierKind::DncOpt;
    cfg.workers.count = 1;
    cfg.banks.count = 592;
    cfg.banks.units_per_bank = 4;
    cfg
}

#[test]
fn calibrated_replies_are_bit_exact_with_native_and_match_offline_replay() {
    let mlp = QuantMlp::random_digits(61);
    let (store, testset) = synth_artifacts("calibrated-e2e", &mlp, 8);
    let n = 9usize;
    let samples: Vec<Vec<f32>> = testset.samples.iter().take(n).map(|s| s.pixels.clone()).collect();

    // Reference run: plain native server over the same artifacts.
    let mut native_cfg = calibrated_cfg(&store);
    native_cfg.backend = BackendKind::Native;
    let (native_server, native_handle) = CoordinatorServer::start(native_cfg).unwrap();
    let native_logits: Vec<Vec<f32>> =
        samples.iter().map(|px| native_handle.submit(px.clone()).unwrap().logits).collect();
    native_server.shutdown();

    // Calibrated run (report-only timing), sequential submissions: each
    // request flushes as its own batch of 1, so the schedule stream is
    // deterministic and replayable offline.
    let (server, handle) = CoordinatorServer::start(calibrated_cfg(&store)).unwrap();
    let model = MultiplierModel::new(MultiplierKind::DncOpt);
    let mut replay = replay_tiler();
    let mut energies = Vec::new();
    for (i, px) in samples.iter().enumerate() {
        let resp = handle.submit(px.clone()).unwrap();
        // numerics: bit-exact with native serving and the functional model
        assert_eq!(resp.logits, native_logits[i], "request {i}");
        assert_eq!(resp.logits, mlp.forward(px, &model), "request {i}");
        assert_eq!(resp.label, mlp.classify(px, &model), "request {i}");
        // cost: populated, and exactly the offline schedule replay
        let want = replay.schedule(&mlp, 1).cost();
        assert!(resp.sim_energy_fj > 0.0 && resp.sim_latency_ps > 0, "request {i}");
        assert_eq!(resp.sim_energy_fj, want.energy_fj, "request {i}");
        assert_eq!(resp.sim_latency_ps, want.latency_ps, "request {i}");
        energies.push(resp.sim_energy_fj);
    }

    // Weight-stationary amortization is visible per request: the first
    // reply paid 2368 LUT programmings, later ones only MAC energy.
    assert!(energies[0] > energies[1], "first request pays programming");
    assert_eq!(energies[1], energies[2], "steady state: MAC energy only");
    let later = handle.submit(samples[0].clone()).unwrap();
    assert_eq!(later.sim_energy_fj, energies[1]);

    let snap = server.metrics().snapshot();
    assert_eq!(snap.requests, n as u64 + 1);
    assert_eq!(snap.failed_batches, 0);
    // one blank-fabric pass programs everything; every later pass hits
    assert_eq!(snap.sim_programs, DIGITS_ELEMS);
    assert_eq!(snap.sim_stationary_hits, DIGITS_ELEMS * n as u64);
    assert!(snap.stationary_hit_rate() > 0.8);
    assert!(snap.sim_p50_latency_ns > 0 && snap.sim_p99_latency_ns >= snap.sim_p50_latency_ns);
    // host-side compute time recorded for every served batch (clamped
    // to the 1 µs histogram floor), alongside the simulated latency
    assert!(snap.host_gemm_p50_us >= 1, "host GEMM time must be recorded");
    assert!(snap.host_gemm_p99_us >= snap.host_gemm_p50_us);
    let report = snap.render();
    assert!(report.contains("sim energy"), "{report}");
    assert!(report.contains("sim latency p50"), "{report}");
    assert!(report.contains("hit-rate"), "{report}");
    assert!(report.contains("host gemm mean"), "{report}");
    server.shutdown();
}

#[test]
fn calibrated_server_survives_concurrent_load() {
    let mlp = QuantMlp::random_digits(67);
    let (store, testset) = synth_artifacts("calibrated-concurrent", &mlp, 8);
    let mut cfg = calibrated_cfg(&store);
    cfg.workers.count = 2;
    // modest fabric: far smaller than the model, forcing reprogramming
    cfg.banks.count = 16;
    let (server, handle) = CoordinatorServer::start(cfg).unwrap();
    let model = MultiplierModel::new(MultiplierKind::DncOpt);
    let n = 40.min(testset.len());
    let mut threads = Vec::new();
    for t in 0..4 {
        let handle = handle.clone();
        let samples: Vec<Vec<f32>> = testset.samples[t * n / 4..(t + 1) * n / 4]
            .iter()
            .map(|s| s.pixels.clone())
            .collect();
        threads.push(std::thread::spawn(move || {
            samples
                .into_iter()
                .map(|px| {
                    let resp = handle.submit(px.clone()).expect("calibrated serve");
                    (px, resp)
                })
                .collect::<Vec<_>>()
        }));
    }
    let mut total = 0usize;
    for t in threads {
        for (px, resp) in t.join().unwrap() {
            total += 1;
            assert_eq!(resp.logits, mlp.forward(&px, &model));
            assert!(resp.sim_energy_fj > 0.0);
            assert!(resp.sim_latency_ps > 0);
        }
    }
    assert_eq!(total, n);
    let snap = server.metrics().snapshot();
    assert_eq!(snap.requests, n as u64);
    assert_eq!(snap.failed_batches, 0);
    assert!(snap.sim_programs > 0, "small fabric must reprogram");
    server.shutdown();
}

#[test]
fn time_scale_gates_served_requests() {
    let mlp = QuantMlp::random_digits(71);
    let (store, testset) = synth_artifacts("calibrated-gated", &mlp, 8);

    // Probe the per-request simulated latency (batch of 1 on a fresh
    // fabric of the same size).
    let probe_ps = replay_tiler().schedule(&mlp, 1).latency_ps;
    assert!(probe_ps > 0);

    // Scale so each batch gates for ~3 ms wall-clock.
    let mut cfg = calibrated_cfg(&store);
    cfg.timing.time_scale = 3_000_000.0 * 1000.0 / probe_ps as f64;
    let (server, handle) = CoordinatorServer::start(cfg).unwrap();
    let t0 = std::time::Instant::now();
    let resp = handle.submit(testset.samples[0].pixels.clone()).unwrap();
    let elapsed = t0.elapsed();
    assert_eq!(resp.sim_latency_ps, probe_ps);
    // sleep() guarantees at least the requested duration (2 ms bound
    // leaves slack for float truncation in the ps→ns mapping)
    let floor = std::time::Duration::from_millis(2);
    assert!(elapsed >= floor, "gated reply came back in {elapsed:?}");
    server.shutdown();
}

#[test]
fn calibrated_with_ideal_multiplier_prices_as_dnc_opt() {
    // `ideal` has no netlist; the calibrated path must serve it anyway,
    // priced with the substituted dnc-opt calibration.
    let mlp = QuantMlp::random_digits(73);
    let (store, testset) = synth_artifacts("calibrated-ideal", &mlp, 8);
    let mut cfg = calibrated_cfg(&store);
    cfg.multiplier = MultiplierKind::Ideal;
    let (server, handle) = CoordinatorServer::start(cfg).unwrap();
    let ideal = MultiplierModel::new(MultiplierKind::Ideal);
    let resp = handle.submit(testset.samples[0].pixels.clone()).unwrap();
    // numerics are ideal...
    assert_eq!(resp.logits, mlp.forward(&testset.samples[0].pixels, &ideal));
    // ...but the cost model is the substituted hardware calibration
    let want = replay_tiler().schedule(&mlp, 1).cost();
    assert_eq!(resp.sim_energy_fj, want.energy_fj);
    assert_eq!(resp.sim_latency_ps, want.latency_ps);
    server.shutdown();
}
