//! End-to-end serving demo: two `repro serve`-equivalent backends
//! behind the `repro route` front tier, driven by a wire client.
//!
//! Run with `cargo run --release --example e2e_serving`. Everything is
//! loopback over synthesized artifacts — no external network, no `make
//! artifacts` — and the demo asserts the router is *transparent*: the
//! logits served through it are bit-identical with a direct in-process
//! `submit` against the same model, and with the functional model.
//! Both backends also host a second tenant (`study`), so model-tagged
//! requests through the router exercise the compiled-plan cache; their
//! replies are asserted bit-identical with the second functional model.

use luna_cim::config::{Config, DispatchPolicy, RouterConfig};
use luna_cim::coordinator::CoordinatorServer;
use luna_cim::multiplier::{MultiplierKind, MultiplierModel};
use luna_cim::net::{Frame, ModelId, NetClient, NetServer, RouterServer};
use luna_cim::nn::{DigitsDataset, QuantMlp};
use luna_cim::runtime::ArtifactStore;

fn main() -> anyhow::Result<()> {
    let mlp = QuantMlp::random_digits(7);
    let mlp_study = QuantMlp::random_digits(8);
    let testset = DigitsDataset::generate(4, 99);
    let model = MultiplierModel::new(MultiplierKind::DncOpt);

    // the second tenant's artifacts, shared by both backends
    let study_dir = luna_cim::util::test_dir("e2e-router-study");
    let study_store = ArtifactStore::new(&study_dir);
    study_store.write_synthetic(&mlp_study, &testset, 8)?;
    let study = ModelId::new("study")?;

    // two independent backend stacks, each on its own loopback port —
    // stand-ins for two `repro serve --listen` processes
    let mut nets = Vec::new();
    let mut servers = Vec::new();
    let mut handles = Vec::new();
    for tag in ["e2e-router-a", "e2e-router-b"] {
        let dir = luna_cim::util::test_dir(tag);
        let store = ArtifactStore::new(&dir);
        store.write_synthetic(&mlp, &testset, 8)?;
        let mut cfg = Config::default();
        cfg.artifacts_dir = store.root().display().to_string();
        cfg.batcher.max_wait_us = 1_000;
        cfg.serving.models =
            vec![("study".to_string(), study_store.root().display().to_string())];
        let (server, handle) = CoordinatorServer::start(cfg)?;
        let net = NetServer::bind(handle.clone(), "127.0.0.1:0", 64)?;
        println!("backend {tag} listening on {}", net.local_addr());
        handles.push(handle);
        nets.push(net);
        servers.push(server);
    }

    let router_cfg = RouterConfig {
        listen: "127.0.0.1:0".into(),
        backends: nets.iter().map(|n| n.local_addr().to_string()).collect(),
        policy: DispatchPolicy::Hash,
        vnodes: 160,
        max_connections: 64,
        probe_ms: 50,
        max_backoff_ms: 500,
    };
    let router = RouterServer::bind(&router_cfg)?;
    println!("router listening on {} (policy {})", router.local_addr(), router_cfg.policy.slug());

    let mut client = NetClient::connect(router.local_addr())?;
    let info = client.info().clone();
    println!("fleet info: in={} out={} max_batch={}", info.in_dim, info.out_dim, info.max_batch);
    anyhow::ensure!(info.models == vec!["study".to_string()], "fleet-agreed tenant list");

    let mut checked = 0usize;
    for sample in testset.samples.iter().take(16) {
        let (label, logits) = match client.infer(&sample.pixels)? {
            Frame::Response { label, logits, .. } => (label as usize, logits.take()),
            other => anyhow::bail!("unexpected reply: {other:?}"),
        };
        let direct = handles[0].submit(sample.pixels.clone())?;
        assert_eq!(logits, direct.logits, "router must be bit-transparent");
        assert_eq!(logits, mlp.forward(&sample.pixels, &model));
        assert_eq!(label, direct.label);
        // the second tenant through the same router connection: served
        // from the plan cache, bit-identical with its functional model
        let tagged = match client.infer_model(study, &sample.pixels)? {
            Frame::Response { logits, .. } => logits.take(),
            other => anyhow::bail!("unexpected study reply: {other:?}"),
        };
        assert_eq!(tagged, mlp_study.forward(&sample.pixels, &model), "study tenant diverged");
        checked += 1;
    }
    println!("{checked}/16 routed replies bit-identical with direct submit (both tenants)");
    print!("{}", router.metrics().snapshot().render());

    router.shutdown();
    for net in nets {
        net.shutdown();
    }
    for server in servers {
        server.shutdown();
    }
    Ok(())
}
