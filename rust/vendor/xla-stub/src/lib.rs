//! API-surface stub of the `xla` (xla-rs) crate.
//!
//! Purpose: give CI *compile* coverage of this repo's feature-gated PJRT
//! path (`cargo check --features pjrt --all-targets`) on runners that
//! have no XLA C++ toolchain. The CI job appends
//! `[patch.crates-io] xla = { path = "vendor/xla-stub" }` to the
//! manifest before checking; real `pjrt` builds patch in the actual
//! vendored xla-rs instead (see the comment in `rust/Cargo.toml`).
//!
//! Every constructor fails with [`Error`] at runtime — this stub can
//! type-check callers but never execute anything. Only the symbols the
//! repo's `runtime/client.rs` touches are provided; if the wrapper grows
//! a new xla call, add it here so CI keeps compiling the real code path.

use std::path::Path;

/// The stub's only error: everything returns it.
#[derive(Debug)]
pub struct Error(&'static str);

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "xla stub: {} (compile-check build, no real XLA linked)", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// Stub of `xla::PjRtClient`.
pub struct PjRtClient(());

impl PjRtClient {
    pub fn cpu() -> Result<Self> {
        Err(Error("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        unreachable!("stub PjRtClient cannot be constructed")
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unreachable!("stub PjRtClient cannot be constructed")
    }
}

/// Stub of `xla::HloModuleProto`.
pub struct HloModuleProto(());

impl HloModuleProto {
    pub fn from_text_file(_path: impl AsRef<Path>) -> Result<Self> {
        Err(Error("HloModuleProto::from_text_file"))
    }
}

/// Stub of `xla::XlaComputation`.
pub struct XlaComputation(());

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        XlaComputation(())
    }
}

/// Stub of `xla::PjRtLoadedExecutable`.
pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[Literal]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unreachable!("stub PjRtLoadedExecutable cannot be constructed")
    }
}

/// Stub of `xla::PjRtBuffer`.
pub struct PjRtBuffer(());

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unreachable!("stub PjRtBuffer cannot be constructed")
    }
}

/// Stub of `xla::Literal`.
pub struct Literal(());

impl Literal {
    pub fn vec1<T: Copy>(_data: &[T]) -> Self {
        Literal(())
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Err(Error("Literal::reshape"))
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        Err(Error("Literal::to_tuple"))
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(Error("Literal::to_vec"))
    }
}
