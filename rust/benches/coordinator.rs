//! End-to-end coordinator benchmarks: batcher, tiler and (when artifacts
//! exist) the full serve path — the paper's system integrated as a
//! serving stack. This is the headline-throughput bench the perf pass
//! tracks in EXPERIMENTS.md §Perf.

use luna_cim::cells::tsmc65_library;
use luna_cim::config::Config;
use luna_cim::coordinator::batcher::Batcher;
use luna_cim::coordinator::request::InferenceRequest;
use luna_cim::coordinator::tiler::{Tiler, UnitCosts};
use luna_cim::coordinator::CoordinatorServer;
use luna_cim::engine::{BackendSpec, ExecBackend};
use luna_cim::multiplier::MultiplierKind;
use luna_cim::net::{loadgen, NetServer, Scenario};
use luna_cim::nn::{DigitsDataset, GemmOptions, QuantMlp};
use luna_cim::runtime::ArtifactStore;
use luna_cim::util::bench::{black_box, Bencher};
use std::time::Duration;

fn main() {
    let b = Bencher::default();

    // 1. batcher hot path
    let mut batcher = Batcher::new(8, Duration::from_micros(500), 4096);
    let mut id = 0u64;
    b.run("batcher push (+ drain every 8th)", 1.0, || {
        id += 1;
        if let Ok(Some(batch)) = batcher.push(InferenceRequest::new(id, vec![0.0; 4])) {
            black_box(batch.padded_to);
        }
    });

    // 2. tiler scheduling (weight-stationary steady state)
    let lib = tsmc65_library();
    let costs = UnitCosts::measure_cached(MultiplierKind::DncOpt, &lib);
    let mlp = QuantMlp::random_digits(1);
    let mut tiler = Tiler::new(16, 4, costs);
    let _ = tiler.schedule(&mlp, 8); // warm: program the fabric
    b.run("tiler schedule 64-32-10 batch=8 (stationary)", mlp.macs() as f64 * 8.0, || {
        black_box(tiler.schedule(&mlp, 8).total_energy_fj);
    });

    // 3. schedule_replay: native vs calibrated backend overhead on the
    //    same batch (the calibrated delta = per-batch Tiler replay; the
    //    report-only gate adds nothing else)
    let mlp_d = QuantMlp::random_digits(2);
    let xs: Vec<f32> = (0..8 * 64).map(|i| (i % 16) as f32 / 16.0).collect();
    let gemm = GemmOptions::default();
    let spec = BackendSpec::Native { mlp: mlp_d.clone(), kind: MultiplierKind::DncOpt, gemm };
    let mut native = spec.build().expect("native backend");
    b.run("schedule_replay native run_batch 64-32-10 b=8", 8.0, || {
        black_box(native.run_batch(&xs, 8, 64).unwrap().logits.len());
    });
    let mut calibrated = BackendSpec::Calibrated {
        mlp: mlp_d,
        kind: MultiplierKind::DncOpt,
        costs,
        banks: 592,
        units_per_bank: 4,
        time_scale: 0.0,
        gemm: GemmOptions::default(),
    }
    .build()
    .expect("calibrated backend");
    b.run("schedule_replay calibrated run_batch 64-32-10 b=8", 8.0, || {
        black_box(calibrated.run_batch(&xs, 8, 64).unwrap().cost.unwrap().latency_ps);
    });

    // 4. shard sweep at fixed offered load: the full wire-protocol stack
    //    over synthesized artifacts (self-contained — no `make
    //    artifacts`), 1/2/4 batcher shards driven by the same open-loop
    //    poisson schedule. Lock-contention relief shows up as throughput
    //    and tail latency; replies stay bit-identical (pinned in
    //    tests/net_serving.rs).
    {
        let mlp = QuantMlp::random_digits(29);
        let dir = luna_cim::util::test_dir("bench-shards");
        let store = ArtifactStore::new(&dir);
        store
            .write_synthetic(&mlp, &DigitsDataset::generate(4, 7), 8)
            .expect("write synthetic artifacts");
        for shards in [1usize, 2, 4] {
            let mut cfg = Config::default();
            cfg.artifacts_dir = dir.display().to_string();
            cfg.workers.count = 4;
            cfg.batcher.shards = shards;
            let (server, handle) = CoordinatorServer::start(cfg).expect("server");
            let net = NetServer::bind(handle, "127.0.0.1:0", 16).expect("bind");
            let opts = loadgen::LoadgenOptions {
                scenarios: vec![Scenario::Poisson],
                loads: vec![4000],
                connections: 4,
                requests_per_level: 2000,
                burst: 16,
                seed: 11,
                retry: false,
                models: vec![],
                mix: loadgen::ModelMix::Zipf,
            };
            let results =
                loadgen::run(&net.local_addr().to_string(), &opts).expect("shard sweep case");
            let r = &results[0];
            println!(
                "bench serve shards={shards} offered=4000/s  served {:>6.0} req/s  \
                 p50 {:>5} us  p99 {:>6} us  reject rate {:.3}",
                r.throughput_rps,
                r.wall_p50_us,
                r.wall_p99_us,
                r.reject_rate()
            );
            net.shutdown();
            server.shutdown();
        }
    }

    // 5. full serve path, if artifacts are present
    let store = ArtifactStore::default_location();
    if !store.exists() {
        println!("(skipping end-to-end serve bench: run `make artifacts`)");
        return;
    }
    let testset = store.load_testset().expect("testset");
    for workers in [1usize, 2, 4] {
        let mut cfg = Config::default();
        cfg.workers.count = workers;
        let (server, handle) = CoordinatorServer::start(cfg).expect("server");
        // concurrent client load, measured end to end
        let clients = 8usize;
        let per_client = 64usize;
        let t0 = std::time::Instant::now();
        let mut threads = Vec::new();
        for c in 0..clients {
            let handle = handle.clone();
            let samples: Vec<Vec<f32>> = testset
                .samples
                .iter()
                .cycle()
                .skip(c * 7)
                .take(per_client)
                .map(|s| s.pixels.clone())
                .collect();
            threads.push(std::thread::spawn(move || {
                for px in samples {
                    let _ = handle.submit(px);
                }
            }));
        }
        for t in threads {
            let _ = t.join();
        }
        let wall = t0.elapsed().as_secs_f64();
        let total = clients * per_client;
        let snap = server.metrics().snapshot();
        println!(
            "bench serve workers={workers:<2} {:>43} {:>10.0} req/s  p50 {:>5} us  p99 {:>6} us  occupancy {:.2}",
            "end-to-end (8 clients x 64 req)",
            total as f64 / wall,
            snap.p50_latency_us,
            snap.p99_latency_us,
            snap.batch_occupancy()
        );
        server.shutdown();
    }
}
