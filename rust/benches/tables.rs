//! Bench target regenerating Tables I and II (component-count scaling)
//! and timing their construction. Every row the paper reports is printed
//! so `cargo bench 2>&1 | tee bench_output.txt` records the reproduction.

use luna_cim::multiplier::{generic, traditional};
use luna_cim::report;
use luna_cim::util::bench::{black_box, Bencher};

fn main() {
    println!("==== Table I — traditional LUT cost (paper Table I) ====");
    print!("{}", report::table1());
    println!("\n==== Table II — traditional vs optimized D&C (paper Table II) ====");
    print!("{}", report::table2());

    // Timing: netlist construction is the "compiler" of this system;
    // regenerating the 16b optimized netlist is the heaviest row.
    println!("\n==== construction timing ====");
    let b = Bencher::default();
    b.run("table1: trad cost rows 3..=8", 6.0, || {
        for k in 3..=8u32 {
            black_box(traditional::cost(k));
        }
    });
    b.run("table2: build 4b optimized netlist", 1.0, || {
        black_box(generic::netlist(4));
    });
    b.run("table2: build 8b optimized netlist", 1.0, || {
        black_box(generic::netlist(8));
    });
    b.run("table2: build 16b optimized netlist", 1.0, || {
        black_box(generic::netlist(16));
    });
    b.run("table2: full regeneration", 1.0, || {
        black_box(report::table2());
    });
}
