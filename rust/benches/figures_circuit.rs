//! Bench target regenerating the circuit-level figures (1–4, 9, 10, 14,
//! 15, 16, 17, 18) with event-simulator throughput measurements.

use luna_cim::cells::tsmc65_library;
use luna_cim::logic::{to_bits, EventSim};
use luna_cim::luna::LunaUnit;
use luna_cim::multiplier::MultiplierKind;
use luna_cim::report;
use luna_cim::util::bench::{black_box, Bencher};
use luna_cim::util::Rng;

fn main() {
    for id in [1u32, 2, 3, 9, 10] {
        println!("==== structure (paper Fig {id}) ====");
        print!("{}", report::fig_structure(id));
    }
    println!("\n==== Fig 14 — transient ====");
    print!("{}", report::figure(14));
    println!("\n==== Fig 15 — energy breakdown ====");
    print!("{}", report::figure(15));
    println!("\n==== Fig 16 — area comparison ====");
    print!("{}", report::figure(16));
    println!("\n==== Fig 17 — bank structure ====");
    print!("{}", report::figure(17));
    println!("\n==== Fig 18 — area pie ====");
    print!("{}", report::figure(18));

    println!("\n==== circuit-simulation timings ====");
    let b = Bencher::default();
    let lib = tsmc65_library();

    // Event-driven transient throughput (stimuli/sec) per configuration.
    for kind in [MultiplierKind::DncOpt, MultiplierKind::Traditional] {
        let netlist = kind.netlist().unwrap();
        let mut sim = EventSim::new(&netlist);
        sim.program(&kind.program_image(6).unwrap());
        let mut rng = Rng::seed_from_u64(1);
        b.run(&format!("event-sim stimulus ({})", kind.name()), 1.0, || {
            black_box(sim.apply(&to_bits(rng.gen_u4() as u64, 4)));
        });
    }

    // Gate-level multiply throughput through a programmed LUNA unit.
    let mut unit = LunaUnit::new(MultiplierKind::DncOpt);
    unit.program(&lib, 6);
    let mut rng = Rng::seed_from_u64(2);
    b.run("LunaUnit::multiply (gate-level + energy)", 1.0, || {
        black_box(unit.multiply(&lib, rng.gen_u4()));
    });

    // Figure regeneration end-to-end.
    let bq = Bencher::quick();
    bq.run("fig14 full regeneration", 1.0, || {
        black_box(report::figure(14));
    });
    bq.run("fig15 full regeneration (64x4 multiplies)", 256.0, || {
        black_box(report::figure(15));
    });
    bq.run("fig18 area report", 1.0, || {
        black_box(report::figure(18));
    });
}
