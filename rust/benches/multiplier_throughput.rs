//! Hot-path throughput of the multiplier models — the L3 performance
//! baseline the perf pass optimizes (EXPERIMENTS.md §Perf).
//!
//! Three tiers:
//! * behavioural `value(w, y)` — what the NN substrate and the analysis
//!   suite execute per MAC;
//! * `MultiplierModel::dot` over realistic layer fan-ins;
//! * full quantized-MLP forward (the per-request functional-model cost).

use luna_cim::multiplier::{MultiplierKind, MultiplierModel};
use luna_cim::nn::QuantMlp;
use luna_cim::util::bench::{black_box, Bencher};
use luna_cim::util::Rng;

fn main() {
    let b = Bencher::default();

    // 1. scalar products
    for kind in MultiplierKind::ALL {
        let mut rng = Rng::seed_from_u64(7);
        b.run(&format!("scalar {:?}", kind), 1.0, || {
            black_box(kind.value(rng.gen_u4(), rng.gen_u4()));
        });
    }

    // 2. dot products at layer fan-in 64
    let mut rng = Rng::seed_from_u64(8);
    let w: Vec<u8> = (0..64).map(|_| rng.gen_u4()).collect();
    let x: Vec<u8> = (0..64).map(|_| rng.gen_u4()).collect();
    for kind in [MultiplierKind::Ideal, MultiplierKind::DncOpt, MultiplierKind::Approx2] {
        let model = MultiplierModel::new(kind);
        b.run(&format!("dot64 {:?}", kind), 64.0, || {
            black_box(model.dot(&w, &x));
        });
    }

    // 3. whole-model forward (64->32->10), per-request functional cost
    let mlp = QuantMlp::random_digits(3);
    let pixels: Vec<f32> = (0..64).map(|_| rng.gen_f64() as f32).collect();
    for kind in [MultiplierKind::Ideal, MultiplierKind::DncOpt, MultiplierKind::Approx] {
        let model = MultiplierModel::new(kind);
        b.run(&format!("mlp-forward {:?}", kind), mlp.macs() as f64, || {
            black_box(mlp.forward(&pixels, &model));
        });
    }
}
