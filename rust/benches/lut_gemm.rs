//! LUT-GEMM kernel shoot-out: per-sample scalar forward vs the batched
//! flat-gather kernel vs the planned kernel (code-sorted weight plans +
//! per-row LUT-strip expansion + a runtime-dispatched strip accumulator
//! + persistent-pool batch tiling) — the speedups the native execution
//! backend buys the serving stack (EXPERIMENTS.md §Perf; acceptance
//! bars: batched ≥ 2× scalar at batch 8, planned beats flat-gather at
//! batch ≥ 8 on the digits model, dispatched SIMD ≥ SWAR per layer).
//!
//! The flat-gather path pays a 2D table index `(w << 4) | x` and a
//! random 256-entry gather per MAC; the planned path compiles weights
//! once into 16-bucket column plans and expands the product table into
//! an L1-resident strip once per input row, so each MAC is a sequential
//! column read plus a strip add — summed by whichever `StripKernel` the
//! host's dispatch guards picked (AVX2 / NEON / SWAR / scalar, all
//! bit-identical). Multi-thread cases cover both tiling modes: batch
//! `rows` (throughput) and per-layer output spans (`outputs` — the
//! batch-1 latency path).
//!
//! Flags (after `--`): `--quick` shrinks the measurement budget for CI
//! smoke runs; `--save-json [PATH]` writes per-kernel MACs/s and
//! µs/inference records to `BENCH_lut_gemm.json` (default), stamped
//! with the dispatched SIMD variant and the host CPU-feature string, so
//! the perf trajectory has data points — CI uploads it as a workflow
//! artifact and asserts the dispatch landed on a non-scalar kernel.

use luna_cim::multiplier::{MultiplierKind, MultiplierModel};
use luna_cim::nn::{
    host_cpu_features, BatchScratch, GemmOptions, GemmPartition, GemmSimd, LayerPlan, PlanScratch,
    QuantLinear, QuantMlp, StripKernel, StripScratch,
};
use luna_cim::util::bench::{black_box, Bencher};
use luna_cim::util::Rng;
use std::fmt::Write as _;

/// One measured kernel configuration, destined for BENCH_lut_gemm.json.
struct Record {
    model: &'static str,
    batch: usize,
    kernel: String,
    macs_per_s: f64,
    mean_ns: f64,
    /// `mean_ns / batch / 1000` — at batch 1 this is the interactive
    /// per-inference latency column the tiling modes compete on.
    us_per_inf: f64,
}

/// Run every kernel on one model at one batch size; returns the
/// flat-vs-planned(t1) speedup for the summary.
fn run_case(
    b: &Bencher,
    model_name: &'static str,
    mlp: &QuantMlp,
    batch: usize,
    scalar_too: bool,
    rng: &mut Rng,
    records: &mut Vec<Record>,
    gemm_threads: &[usize],
) -> f64 {
    let model = MultiplierModel::new(MultiplierKind::DncOpt);
    let in_dim = mlp.input_dim();
    let xs: Vec<f32> = (0..batch * in_dim).map(|_| rng.gen_range_f32(0.0, 1.0)).collect();
    let macs = (mlp.macs() * batch as u64) as f64;
    let mut push = |kernel: String, r: &luna_cim::util::bench::BenchResult| {
        records.push(Record {
            model: model_name,
            batch,
            kernel,
            macs_per_s: r.throughput_per_sec(),
            mean_ns: r.mean_ns,
            us_per_inf: r.mean_ns / batch.max(1) as f64 / 1000.0,
        });
    };

    if scalar_too {
        let r = b.run(&format!("{model_name} per-sample forward x{batch}"), macs, || {
            for row in 0..batch {
                black_box(mlp.forward(&xs[row * in_dim..(row + 1) * in_dim], &model));
            }
        });
        push("scalar".to_string(), &r);
    }

    let mut scratch = BatchScratch::default();
    let flat = b.run(&format!("{model_name} flat-gather GEMM x{batch}"), macs, || {
        black_box(mlp.forward_batch_with(&xs, batch, &model, &mut scratch));
    });
    push("flat".to_string(), &flat);

    let mut planned_t1_ns = f64::MAX;
    // One record per distinct (effective threads, tiling) pair: `rows`
    // tiling clamps to the batch row count (0 resolves to the core
    // count), and a single worker runs the full span under either mode,
    // so duplicates are skipped — the JSON never reports a fake
    // multi-thread data point.
    let mut seen: Vec<String> = Vec::new();
    for &threads in gemm_threads {
        for partition in [GemmPartition::Rows, GemmPartition::Outputs] {
            let plan = mlp.plan_with(GemmOptions { threads, simd: GemmSimd::Auto, partition });
            let effective = match partition {
                GemmPartition::Rows => plan.threads().min(batch.max(1)),
                _ => plan.threads(),
            };
            let kernel = if effective == 1 {
                "planned-t1".to_string()
            } else {
                format!("planned-t{effective}-{}", partition.slug())
            };
            if seen.contains(&kernel) {
                continue;
            }
            seen.push(kernel.clone());
            let mut pscratch = PlanScratch::default();
            let r = b.run(&format!("{model_name} {kernel} GEMM x{batch}"), macs, || {
                black_box(plan.forward_batch_with(&xs, batch, &model, &mut pscratch));
            });
            if effective == 1 {
                planned_t1_ns = r.mean_ns;
            }
            push(kernel, &r);
        }
    }
    flat.mean_ns / planned_t1_ns.max(1e-9)
}

/// Race the strip accumulators on one layer: the retained scalar
/// reference vs the portable SWAR kernel vs the host's dispatched SIMD
/// kernel (when the dispatch resolves past SWAR). All are bit-identical
/// — `tests/gemm_plan.rs` pins that; this quantifies the win per layer.
/// Returns the SWAR-vs-scalar speedup plus the SIMD-vs-SWAR speedup if
/// a SIMD kernel dispatched.
fn run_strip_case(
    b: &Bencher,
    model_name: &'static str,
    layer: &QuantLinear,
    rows: usize,
    rng: &mut Rng,
    records: &mut Vec<Record>,
) -> (f64, Option<f64>) {
    let model = MultiplierModel::new(MultiplierKind::DncOpt);
    let plan = LayerPlan::compile(layer);
    assert!(plan.uses_strip(), "strip race needs a strip-path layer");
    let in_dim = layer.in_dim;
    let macs = (layer.macs() * rows as u64) as f64;
    let xq: Vec<u8> = (0..rows * in_dim).map(|_| rng.gen_range_u64(0, 16) as u8).collect();
    let mut scratch = StripScratch::default();
    let mut out = Vec::new();
    let swar = b.run(&format!("{model_name} strip SWAR x{rows}"), macs, || {
        plan.gemm_rows_into(&xq, rows, &model, &mut scratch, &mut out);
        black_box(out.len());
    });
    let scalar = b.run(&format!("{model_name} strip scalar x{rows}"), macs, || {
        plan.gemm_rows_into_scalar(&xq, rows, &model, &mut scratch, &mut out);
        black_box(out.len());
    });
    let dispatched = GemmSimd::Auto.resolve();
    let simd = (dispatched != StripKernel::Swar).then(|| {
        b.run(&format!("{model_name} strip {} x{rows}", dispatched.slug()), macs, || {
            plan.gemm_rows_into_kernel(&xq, rows, &model, &mut scratch, &mut out, dispatched);
            black_box(out.len());
        })
    });
    let mut push = |kernel: String, r: &luna_cim::util::bench::BenchResult| {
        records.push(Record {
            model: model_name,
            batch: rows,
            kernel,
            macs_per_s: r.throughput_per_sec(),
            mean_ns: r.mean_ns,
            us_per_inf: r.mean_ns / rows.max(1) as f64 / 1000.0,
        });
    };
    push("strip-swar".to_string(), &swar);
    push("strip-scalar".to_string(), &scalar);
    if let Some(r) = &simd {
        push(format!("strip-{}", dispatched.slug()), r);
    }
    (
        scalar.mean_ns / swar.mean_ns.max(1e-9),
        simd.as_ref().map(|r| swar.mean_ns / r.mean_ns.max(1e-9)),
    )
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let quick = args.iter().any(|a| a == "--quick");
    let save_json: Option<String> = args.iter().position(|a| a == "--save-json").map(|i| {
        match args.get(i + 1) {
            Some(v) if !v.starts_with("--") => v.clone(),
            _ => "BENCH_lut_gemm.json".to_string(),
        }
    });
    let b = if quick { Bencher::quick() } else { Bencher::default() };
    let mut rng = Rng::seed_from_u64(12);
    let mut records = Vec::new();
    let dispatched = GemmSimd::Auto.resolve();
    let cpu = host_cpu_features();
    println!("strip kernel dispatch: {} (host: {cpu})", dispatched.slug());

    // The serving-shaped digits classifier (64 → 32 → 10).
    let digits = QuantMlp::random_digits(5);
    let mut planned_speedup_at_8 = 0.0f64;
    for batch in [1usize, 8, 64] {
        let s =
            run_case(&b, "digits-64-32-10", &digits, batch, true, &mut rng, &mut records, &[1, 2]);
        println!("  -> digits batch {batch}: planned t1 is {s:.2}x the flat-gather kernel");
        if batch == 8 {
            planned_speedup_at_8 = s;
        }
    }

    // One wide 256×256 layer — the shape where strip expansion amortizes
    // over many output rows and threading has real work to split.
    let wide = {
        let w: Vec<Vec<f32>> = (0..256)
            .map(|_| (0..256).map(|_| rng.gen_range_f32(-0.4, 0.4)).collect())
            .collect();
        let bias: Vec<f32> = (0..256).map(|_| rng.gen_range_f32(-0.1, 0.1)).collect();
        QuantMlp::new(vec![QuantLinear::from_float(&w, bias, 1.0, false)])
    };
    // Batch 1 included on purpose: `rows` tiling degenerates to t1 there
    // while `outputs` spans still fan out — the latency-shape contrast
    // the `gemm.partition` knob exists for.
    for batch in [1usize, 8, 64] {
        let s =
            run_case(&b, "wide-256x256", &wide, batch, false, &mut rng, &mut records, &[1, 2, 0]);
        println!("  -> wide batch {batch}: planned t1 is {s:.2}x the flat-gather kernel");
    }

    // Per-layer strip-accumulator race (scalar reference vs packed SWAR
    // lanes vs the dispatched SIMD kernel): the two strip-path layer
    // shapes of the suite, at a serving row count.
    let digits_hidden = &digits.layers[0]; // 64 → 32, strip path
    let (s, simd) = run_strip_case(&b, "layer-64x32", digits_hidden, 8, &mut rng, &mut records);
    println!("  -> layer 64x32: SWAR strip accumulate is {s:.2}x the scalar strip");
    if let Some(s) = simd {
        println!("  -> layer 64x32: {} strip is {s:.2}x the SWAR strip", dispatched.slug());
    }
    let wide_layer = &wide.layers[0]; // 256 → 256
    let (s, simd) = run_strip_case(&b, "layer-256x256", wide_layer, 8, &mut rng, &mut records);
    println!("  -> layer 256x256: SWAR strip accumulate is {s:.2}x the scalar strip");
    if let Some(s) = simd {
        println!("  -> layer 256x256: {} strip is {s:.2}x the SWAR strip", dispatched.slug());
    }

    println!(
        "planned/flat speedup at digits batch 8: {planned_speedup_at_8:.2}x \
         (target: planned beats flat at batch >= 8)"
    );

    if let Some(path) = save_json {
        let json = render_json(&records, dispatched.slug(), &cpu);
        std::fs::write(&path, json).expect("write bench json");
        println!("wrote {} records to {path}", records.len());
    }
}

/// Hand-rolled JSON (no serde in this offline image): a header naming
/// the dispatched SIMD variant and the host CPU-feature string, then
/// one record per (model, batch, kernel) with MACs/s, mean ns/iter and
/// µs per inference.
fn render_json(records: &[Record], simd: &str, cpu: &str) -> String {
    let mut out = String::from("{\n  \"bench\": \"lut_gemm\",\n");
    let _ = writeln!(out, "  \"simd\": \"{simd}\",");
    let _ = writeln!(out, "  \"cpu\": \"{cpu}\",");
    out.push_str("  \"cases\": [\n");
    for (i, r) in records.iter().enumerate() {
        let _ = write!(
            out,
            "    {{\"model\": \"{}\", \"batch\": {}, \"kernel\": \"{}\", \
             \"macs_per_s\": {:.1}, \"mean_ns\": {:.1}, \"us_per_inf\": {:.3}}}",
            r.model, r.batch, r.kernel, r.macs_per_s, r.mean_ns, r.us_per_inf
        );
        out.push_str(if i + 1 < records.len() { ",\n" } else { "\n" });
    }
    out.push_str("  ]\n}\n");
    out
}
