//! Batched native LUT-GEMM vs the scalar per-sample forward — the
//! speedup the native execution backend buys the serving stack
//! (EXPERIMENTS.md §Perf; the acceptance bar is ≥2× at batch 8 on the
//! digits-shaped model).
//!
//! The per-sample loop is what `QuantLinear::accumulate` costs a worker
//! that executes a batch one request at a time: one quantize + two Vec
//! allocations per layer per sample, and a masked `mul` per MAC. The
//! batched path quantizes the whole batch once per layer, flat-gathers
//! the 256-entry table, hoists the zero-point correction per row, and
//! reuses one scratch buffer across layers and batches.

use luna_cim::multiplier::{MultiplierKind, MultiplierModel};
use luna_cim::nn::{BatchScratch, QuantMlp};
use luna_cim::util::bench::{black_box, Bencher};
use luna_cim::util::Rng;

fn main() {
    let b = Bencher::default();
    let mlp = QuantMlp::random_digits(5);
    let model = MultiplierModel::new(MultiplierKind::DncOpt);
    let in_dim = mlp.input_dim();
    let mut rng = Rng::seed_from_u64(12);

    let mut speedup_at_8 = 0.0f64;
    for batch in [1usize, 8, 32, 128] {
        let xs: Vec<f32> = (0..batch * in_dim).map(|_| rng.gen_range_f32(0.0, 1.0)).collect();
        let macs = (mlp.macs() * batch as u64) as f64;

        let scalar = b.run(&format!("per-sample forward x{batch}"), macs, || {
            for r in 0..batch {
                black_box(mlp.forward(&xs[r * in_dim..(r + 1) * in_dim], &model));
            }
        });

        let mut scratch = BatchScratch::default();
        let batched = b.run(&format!("native batched GEMM x{batch}"), macs, || {
            black_box(mlp.forward_batch_with(&xs, batch, &model, &mut scratch));
        });

        let speedup = scalar.mean_ns / batched.mean_ns.max(1e-9);
        println!("  -> batch {batch}: batched GEMM {speedup:.2}x the per-sample loop");
        if batch == 8 {
            speedup_at_8 = speedup;
        }
    }
    println!(
        "speedup at batch 8: {speedup_at_8:.2}x (target >= 2x on the digits-shaped model)"
    );
}
