//! Bench target regenerating the statistical figures (5, 6, 7, 8, 11,
//! 12, 13) with timings. Prints every series so the bench log doubles as
//! the reproduction record.

use luna_cim::analysis::{error_map, hamming, mae, probability};
use luna_cim::multiplier::MultiplierKind;
use luna_cim::report;
use luna_cim::util::bench::{black_box, Bencher};

fn main() {
    println!("==== Fig 5 — LSB-side product distribution ====");
    print!("{}", report::fig5());
    println!("\n==== Fig 6 — Hamming-distance candidate sweep ====");
    print!("{}", report::fig6());
    println!("\n==== Fig 7 / 8 — ApproxD&C error map & histogram ====");
    print!("{}", report::fig_heatmap(7));
    print!("{}", report::fig_histogram(8));
    println!("\n==== Fig 11 / 12 — ApproxD&C2 error map & histogram ====");
    print!("{}", report::fig_heatmap(11));
    print!("{}", report::fig_histogram(12));
    println!("\n==== Fig 13 — MAE study (100 iterations) ====");
    print!("{}", report::fig13(100, 2024));

    println!("\n==== analysis timings ====");
    let b = Bencher::default();
    b.run("fig5: exact pmf", 64.0, || {
        black_box(probability::lsb_product_pmf());
    });
    b.run("fig6: hamming sweep (64 candidates)", 64.0, || {
        black_box(hamming::mean_hamming_per_candidate());
    });
    b.run("fig7/11: one 16x16 error map", 256.0, || {
        black_box(error_map::error_map(MultiplierKind::Approx));
    });
    b.run("fig13: element MAE, 10k pairs", 10_000.0, || {
        black_box(mae::element_mae(MultiplierKind::Approx2, 10_000, 7));
    });
    let bq = Bencher::quick();
    bq.run("fig13: full study (100 iters, 7 configs)", 700.0, || {
        black_box(mae::fig13_study(100, 7));
    });
}
