//! Fig 14 reproduction: event-driven transient of the mux-based
//! multiplier with W = 0110 and Y stepping through 1010, 1011, 0011, 1100.
//!
//! Prints the waveform table (text analogue of the paper's scope shot),
//! the per-stimulus settle times, and glitch-aware switching statistics,
//! then writes `fig14.csv` next to the binary for external plotting.
//!
//! Run: `cargo run --release --example transient_waveform`

use luna_cim::logic::{to_bits, BusTrace, EventSim};
use luna_cim::multiplier::MultiplierKind;

fn main() {
    for kind in [MultiplierKind::DncOpt, MultiplierKind::Approx, MultiplierKind::Approx2] {
        let netlist = kind.netlist().unwrap();
        let mut sim = EventSim::new(&netlist);
        sim.watch_bus("Y");
        sim.watch_bus("OUT");
        sim.program(&kind.program_image(0b0110).unwrap());

        let ys = [0b1010u64, 0b1011, 0b0011, 0b1100];
        println!("== {} : W=0110, Y = 1010, 1011, 0011, 1100 ==", kind.name());
        let vectors: Vec<Vec<bool>> = ys.iter().map(|&y| to_bits(y, 4)).collect();
        let waves = sim.run_schedule(&vectors, 2_000);
        let trace = BusTrace::new(waves);
        print!("{}", trace.render());
        let stats = sim.stats();
        println!(
            "transitions {} (glitches included), events {}, worst settle {} ps\n",
            stats.transitions, stats.events, stats.settle_time_ps
        );
        if kind == MultiplierKind::DncOpt {
            std::fs::write("fig14.csv", trace.to_csv()).expect("write fig14.csv");
            println!("wrote fig14.csv\n");
        }
    }

    // Per-stimulus settle-time detail for the paper configuration.
    let netlist = MultiplierKind::DncOpt.netlist().unwrap();
    let mut sim = EventSim::new(&netlist);
    sim.program(&MultiplierKind::DncOpt.program_image(0b0110).unwrap());
    println!("-- per-stimulus settle times (critical path view) --");
    for y in [0b1010u64, 0b1011, 0b0011, 0b1100] {
        let dt = sim.apply(&to_bits(y, 4));
        let out = sim.bus_value(&netlist.find_out_bus("OUT").unwrap().clone());
        println!("  Y={y:04b} -> OUT={out:3}  settle {dt:4} ps");
    }
}
