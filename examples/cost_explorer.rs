//! Cost explorer: how LUT-multiplier costs scale with operand width.
//!
//! Regenerates Tables I and II, extends the optimized-D&C scaling beyond
//! the paper (every even width 4..=16, built structurally), and prints the
//! area/transistor crossover against the traditional approach — the
//! scalability argument that motivates the whole paper.
//!
//! Run: `cargo run --release --example cost_explorer`

use luna_cim::cells::{tsmc65_library, CellKind};
use luna_cim::multiplier::{generic, traditional};
use luna_cim::report;

fn main() {
    println!("{}", report::table1());
    println!("{}", report::table2());

    let lib = tsmc65_library();
    println!("-- optimized D&C scaling, every even width (by construction) --");
    println!(
        "{:>5} {:>8} {:>8} {:>6} {:>6} {:>12} {:>12} {:>10}",
        "width", "SRAM", "MUX", "HA", "FA", "transistors", "trad-xtors", "ratio"
    );
    for n in (4..=16u32).step_by(2) {
        let netlist = generic::netlist(n);
        let cost = netlist.cost_report();
        let t = cost.transistors(&lib);
        let trad = traditional::cost(n).transistors(&lib);
        println!(
            "{:>4}b {:>8} {:>8} {:>6} {:>6} {:>12} {:>12} {:>9.1}x",
            n,
            cost.count(CellKind::SramCell),
            cost.count(CellKind::Mux2),
            cost.count(CellKind::HalfAdder),
            cost.count(CellKind::FullAdder),
            t,
            trad,
            trad as f64 / t as f64,
        );
    }

    println!("\n-- area benefit at 4 bits (paper abstract: ~3.7x less area) --");
    let trad4 = traditional::cost(4).routed_area_um2(&lib);
    for (name, cost) in [
        ("D&C", luna_cim::multiplier::dnc::cost()),
        ("Optimized D&C", luna_cim::multiplier::dnc_opt::cost()),
        ("ApproxD&C", luna_cim::multiplier::approx::cost()),
        ("ApproxD&C 2", luna_cim::multiplier::approx2::cost()),
    ] {
        let a = cost.routed_area_um2(&lib);
        println!("  {:<16} {:>8.1} um2   ({:.2}x smaller than traditional)", name, a, trad4 / a);
    }
}
