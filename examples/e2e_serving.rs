//! END-TO-END DRIVER: the full three-layer system on a real workload.
//!
//! Proves all layers compose (recorded in EXPERIMENTS.md):
//!
//!   quantized model --+--> native batched LUT-GEMM workers (default)
//!                     +--> calibrated workers: native numerics + per-worker
//!                          Tiler schedule replay (pass `calibrated`; an
//!                          optional second argument sets the ps→wall-clock
//!                          time_scale, 0 = report-only)
//!                     +--> PJRT workers over AOT HLO text (--features pjrt,
//!                          pass `pjrt` as the first argument)
//!   Rust coordinator: dynamic batcher -> router -> workers
//!   LUNA fabric cost model: gate-level-calibrated energy & cycles
//!
//! For every multiplier variant it serves the exported digits test set
//! through the batching coordinator under concurrent client load and
//! reports accuracy, latency percentiles, throughput, batch occupancy
//! and the simulated CiM energy (programming + MACs). A final pass
//! re-serves the test set over the **wire protocol** (loopback TCP
//! front-end, see `net` in the crate docs) and checks the responses
//! stay bit-identical with direct in-process submission.
//!
//! Run: `make artifacts && cargo run --release --example e2e_serving`
//! (the native backend needs only manifest/weights/testset from the
//! artifact step — no HLO files).

use luna_cim::config::{BackendKind, Config};
use luna_cim::coordinator::CoordinatorServer;
use luna_cim::multiplier::MultiplierKind;
use luna_cim::net::{Frame, NetClient, NetServer};
use luna_cim::runtime::ArtifactStore;
use std::time::Instant;

fn main() -> luna_cim::Result<()> {
    let backend = match std::env::args().nth(1).as_deref() {
        Some(s) => BackendKind::from_arg(s)?,
        None => BackendKind::Native,
    };
    let time_scale: f64 = match std::env::args().nth(2) {
        Some(s) => s.parse().map_err(|_| anyhow::anyhow!("bad time-scale `{s}`"))?,
        None => 0.0,
    };
    let store = ArtifactStore::default_location();
    let meta = store.manifest()?;
    let testset = store.load_testset()?;
    println!(
        "model {:?} | batch {} | {} test samples | backend {} | quantized(ideal) accuracy from aot: {:.3}\n",
        meta.dims,
        meta.batch,
        testset.len(),
        backend.slug(),
        meta.train_accuracy
    );

    const CLIENTS: usize = 8;
    const PASSES: usize = 4; // serve the test set this many times

    println!(
        "{:<16} {:>8} {:>10} {:>9} {:>9} {:>9} {:>10} {:>12} {:>11}",
        "variant", "acc", "req/s", "mean us", "p50 us", "p99 us", "occupancy", "energy/req", "sim ns/req"
    );
    for kind in [
        MultiplierKind::Ideal,
        MultiplierKind::DncOpt,
        MultiplierKind::Approx,
        MultiplierKind::Approx2,
    ] {
        let mut cfg = Config::default();
        cfg.multiplier = kind;
        cfg.backend = backend;
        cfg.timing.time_scale = time_scale;
        let (server, handle) = CoordinatorServer::start(cfg)?;

        let t0 = Instant::now();
        let mut threads = Vec::new();
        for c in 0..CLIENTS {
            let handle = handle.clone();
            let samples: Vec<(Vec<f32>, usize)> = testset
                .samples
                .iter()
                .cycle()
                .skip(c * testset.len() / CLIENTS)
                .take(testset.len() * PASSES / CLIENTS)
                .map(|s| (s.pixels.clone(), s.label))
                .collect();
            threads.push(std::thread::spawn(move || {
                let mut correct = 0usize;
                let mut total = 0usize;
                let mut sim_ps = 0u64;
                for (px, label) in samples {
                    let resp = handle.submit(px).expect("serve");
                    total += 1;
                    sim_ps += resp.sim_latency_ps;
                    if resp.label == label {
                        correct += 1;
                    }
                }
                (correct, total, sim_ps)
            }));
        }
        let (mut correct, mut total, mut sim_ps) = (0usize, 0usize, 0u64);
        for t in threads {
            let (c, n, s) = t.join().unwrap();
            correct += c;
            total += n;
            sim_ps += s;
        }
        let wall = t0.elapsed().as_secs_f64();
        let snap = server.metrics().snapshot();
        println!(
            "{:<16} {:>8.3} {:>10.0} {:>9.0} {:>9} {:>9} {:>10.2} {:>9.1} nJ {:>11.2}",
            kind.name(),
            correct as f64 / total as f64,
            total as f64 / wall,
            snap.mean_latency_us,
            snap.p50_latency_us,
            snap.p99_latency_us,
            snap.batch_occupancy(),
            snap.sim_energy_fj / total as f64 / 1e6,
            sim_ps as f64 / total as f64 / 1e3,
        );
        if backend == BackendKind::Calibrated {
            println!(
                "{:<16} sim latency p50 {} ns p99 {} ns | programs {} | stationary hit-rate {:.3}",
                "", // indent under the variant row
                snap.sim_p50_latency_ns,
                snap.sim_p99_latency_ns,
                snap.sim_programs,
                snap.stationary_hit_rate(),
            );
        }
        server.shutdown();
    }

    // Wire-protocol pass: the same coordinator behind the TCP
    // front-end — loopback-served responses must be bit-identical with
    // the direct in-process path.
    let mut cfg = Config::default();
    cfg.backend = backend;
    cfg.timing.time_scale = time_scale;
    let (server, handle) = CoordinatorServer::start(cfg.clone())?;
    let net = NetServer::bind(handle.clone(), "127.0.0.1:0", cfg.net.max_connections)?;
    let mut client = NetClient::connect(net.local_addr())?;
    let n = testset.len().min(64);
    let mut identical = 0usize;
    for s in testset.samples.iter().take(n) {
        match client.infer(&s.pixels)? {
            Frame::Response { label, logits, .. } => {
                let direct = handle.submit(s.pixels.clone())?;
                if direct.label == label as usize && direct.logits == logits {
                    identical += 1;
                }
            }
            other => anyhow::bail!("unexpected wire reply {other:?}"),
        }
    }
    println!(
        "\nwire protocol ({} on {}): {identical}/{n} loopback responses \
         bit-identical with direct submit",
        client.info().backend,
        net.local_addr()
    );
    anyhow::ensure!(identical == n, "wire/direct divergence: only {identical}/{n} bit-identical");
    net.shutdown();
    server.shutdown();

    println!(
        "\nnotes:\n\
         * accuracy: exact LUT variants match IDEAL; ApproxD&C collapses on a\n\
           trained classifier while ApproxD&C 2 degrades gracefully;\n\
         * energy/req is the simulated CiM cost (weight-stationary: later\n\
           batches pay only MAC energy, no reprogramming);\n\
         * sim ns/req is the modelled in-array latency (cycles x measured\n\
           critical path), independent of host wall-clock;\n\
         * with `calibrated`, pricing runs inside each worker on its own\n\
           weight-stationary fabric, and a non-zero time_scale makes the\n\
           simulated latency gate every reply."
    );
    Ok(())
}
