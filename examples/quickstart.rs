//! Quickstart: the LUNA-CiM library in five minutes.
//!
//! Builds every multiplier configuration, multiplies through the
//! behavioural models AND the gate-level netlists, prints the paper's
//! headline cost table, and runs the §IV.B stimulus on a programmed
//! LUNA unit with energy accounting.
//!
//! Run: `cargo run --release --example quickstart`

use luna_cim::cells::tsmc65_library;
use luna_cim::luna::LunaUnit;
use luna_cim::multiplier::{MultiplierKind, MultiplierModel};

fn main() {
    let lib = tsmc65_library();

    // 1. Behavioural models: a 4b x 4b multiply under every configuration.
    let (w, y) = (6u8, 11u8);
    println!("-- {w} x {y} under every configuration --");
    for kind in MultiplierKind::ALL {
        let m = MultiplierModel::new(kind);
        println!("  {:<18} -> {:3}  (error {:+})", kind.name(), m.mul(w, y), kind.error(w, y));
    }

    // 2. Component costs (the paper's Figs 1-3, 9, 10 inventories).
    println!("\n-- component inventory / area --");
    for kind in MultiplierKind::PAPER_CONFIGS {
        let cost = kind.netlist().unwrap().cost_report();
        println!(
            "  {:<18} {}  | {} transistors | {:.0} um2 routed",
            kind.name(),
            cost,
            cost.transistors(&lib),
            cost.routed_area_um2(&lib)
        );
    }

    // 3. A programmed LUNA unit running the paper's transient stimulus
    //    (W = 0110; Y = 1010, 1011, 0011, 1100) with measured energy.
    println!("\n-- gate-level LUNA unit, paper SSIV.B stimulus --");
    let mut unit = LunaUnit::new(MultiplierKind::DncOpt);
    unit.program(&lib, 0b0110);
    for y in [0b1010u8, 0b1011, 0b0011, 0b1100] {
        let out = unit.multiply(&lib, y);
        println!("  W=0110 x Y={y:04b} -> OUT={out:08b} ({out})");
    }
    println!(
        "  avg multiply energy: {:.2} fJ (paper: 47.96 fJ)",
        unit.avg_multiply_energy_fj()
    );
    println!("  unit area: {:.1} um2 (paper: 287 um2)", unit.area_um2(&lib));
}
