//! Fig 13 reproduction: MAE of each multiplier configuration, both on
//! raw random 4-bit pairs (the paper's MATLAB study) and inside neural
//! networks, plus classification accuracy on the digits test set when
//! the trained artifacts are present.
//!
//! Run: `cargo run --release --example accuracy_study`

use luna_cim::analysis::{error_map, mae};
use luna_cim::multiplier::{MultiplierKind, MultiplierModel};
use luna_cim::runtime::ArtifactStore;

fn main() {
    // 1. Element-level MAE: the 100-iteration random study + the exact
    //    exhaustive limit.
    println!("-- element-level MAE vs IDEAL (paper Fig 13 granularity) --");
    println!("{:<18} {:>14} {:>14}", "configuration", "100-iter MAE", "exhaustive MAE");
    for kind in MultiplierKind::ALL {
        println!(
            "{:<18} {:>14.4} {:>14.4}",
            kind.name(),
            mae::element_mae(kind, 100, 2024),
            mae::element_mae_exhaustive(kind)
        );
    }

    // 2. Error structure of the approximations (Figs 7/8/11/12 numbers).
    println!("\n-- approximation error structure --");
    for kind in [MultiplierKind::Approx, MultiplierKind::Approx2] {
        let m = error_map::error_map(kind);
        let (lo, hi) = m.range();
        println!(
            "  {:<14} error range [{lo}, {hi}], bias {:+.3}, MAE {:.3}",
            kind.name(),
            m.mean_error(),
            m.mean_abs_error()
        );
    }

    // 3. Network-level MAE (random networks, deterministic seeds).
    println!("\n-- network-level MAE vs IDEAL (100 random inputs) --");
    for r in mae::fig13_study(100, 2024) {
        println!("  {:<18} element {:>8.4}   network {:>8.4}", r.kind.name(), r.element_mae, r.network_mae);
    }

    // 4. Trained-model accuracy (needs `make artifacts`).
    let store = ArtifactStore::default_location();
    match (store.load_mlp(), store.load_testset()) {
        (Ok(mlp), Ok(testset)) => {
            println!("\n-- digits classifier accuracy ({} test samples) --", testset.len());
            for kind in [
                MultiplierKind::Ideal,
                MultiplierKind::DncOpt,
                MultiplierKind::Approx,
                MultiplierKind::Approx2,
            ] {
                let model = MultiplierModel::new(kind);
                let acc = testset.accuracy(|px| mlp.classify(px, &model));
                println!("  {:<18} accuracy {:.3}", kind.name(), acc);
            }
            println!(
                "\nfinding: ApproxD&C's one-sided error (always undershooting by\n\
                 Z_LSB) collapses the trained classifier, while ApproxD&C 2's\n\
                 W-dependent, sign-balanced estimate retains most accuracy —\n\
                 the quantitative face of the paper's SSIII.C 'balanced error\n\
                 distribution' argument."
            );
        }
        _ => println!("\n(skipping trained-model study: run `make artifacts` first)"),
    }
}
