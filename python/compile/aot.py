"""AOT build: train, quantize, export artifacts, lower HLO text.

Run via ``make artifacts`` (equivalently ``cd python && python -m
compile.aot --out-dir ../artifacts``). Python never runs again after this:
the Rust coordinator loads the HLO text through PJRT and the metadata
through the kv files.

Interchange is **HLO text**, not serialized protos: jax >= 0.5 emits
HloModuleProto with 64-bit instruction ids that xla_extension 0.5.1 (the
version the published ``xla`` crate binds) rejects; the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).
"""

import argparse
import functools
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import data, model
from .kernels.luna_matmul import VARIANTS, luna_multiply

BATCH = 8
TRAIN_PER_DIGIT = 60
TEST_PER_DIGIT = 20
TRAIN_SEED = 1234
TEST_SEED = 5678


def to_hlo_text(lowered) -> str:
    """Lower a jitted function to HLO text (see module docstring).

    ``as_hlo_text(True)`` = print_large_constants: the default printer
    elides big literals as ``constant({...})`` and the text parser then
    silently zero-fills them — the baked weight matrices MUST be printed
    in full for the Rust side to reproduce the numerics.
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    text = comp.as_hlo_text(True)
    assert "constant({...})" not in text, "elided constant survived printing"
    return text


def lower_mlp_variant(qmodel, variant: str) -> str:
    """HLO text of the batched quantized forward pass for one variant."""

    def fwd(x):
        return (model.quant_forward(qmodel, x, variant=variant),)

    spec = jax.ShapeDtypeStruct((BATCH, model.DIMS[0]), jnp.float32)
    return to_hlo_text(jax.jit(fwd).lower(spec))


def lower_mult_variant(variant: str) -> str:
    """HLO text of the standalone elementwise 4b multiplier (16x16 grid).

    Takes float (PJRT-side convenience), rounds to codes, multiplies via
    the Pallas kernel, returns float products — used by Rust integration
    tests to cross-check the gate-level netlists bit-for-bit.
    """

    def mult(w, y):
        wq = jnp.clip(jnp.round(w), 0, 15).astype(jnp.int32)
        yq = jnp.clip(jnp.round(y), 0, 15).astype(jnp.int32)
        return (luna_multiply(wq, yq, variant=variant).astype(jnp.float32),)

    spec = jax.ShapeDtypeStruct((16, 16), jnp.float32)
    return to_hlo_text(jax.jit(mult).lower(spec, spec))


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--out-dir", default="../artifacts")
    parser.add_argument("--steps", type=int, default=400)
    parser.add_argument("--quick", action="store_true", help="tiny run for CI smoke")
    args = parser.parse_args()
    out = args.out_dir
    os.makedirs(out, exist_ok=True)

    steps = 30 if args.quick else args.steps
    train_n = 10 if args.quick else TRAIN_PER_DIGIT
    test_n = 4 if args.quick else TEST_PER_DIGIT

    print(f"[aot] generating data (train {train_n}/digit, test {test_n}/digit)")
    train_x, train_y = data.generate(train_n, TRAIN_SEED)
    test_x, test_y = data.generate(test_n, TEST_SEED)

    print(f"[aot] training float model for {steps} steps")
    params, train_acc = model.train_float(train_x, train_y, seed=0, steps=steps)
    qmodel = model.quantize_model(params)
    test_acc = model.quant_accuracy(qmodel, test_x, test_y, "ideal")
    print(f"[aot] float train acc {train_acc:.3f}; quantized(ideal) test acc {test_acc:.3f}")

    # --- artifacts ---
    with open(os.path.join(out, "weights.txt"), "w") as f:
        f.write(model.weights_text(qmodel))
    with open(os.path.join(out, "testset.bin"), "wb") as f:
        f.write(data.export_testset(test_x, test_y))

    for variant in VARIANTS:
        hlo = lower_mlp_variant(qmodel, variant)
        slug = variant.replace("_", "-")
        # rust slugs: ideal, dnc, approx, approx2 + dnc-opt alias below
        path = os.path.join(out, f"mlp_{slug}.hlo.txt")
        with open(path, "w") as f:
            f.write(hlo)
        print(f"[aot] wrote {path} ({len(hlo)} chars)")
        mult_hlo = lower_mult_variant(variant)
        mpath = os.path.join(out, f"mult_{slug}.hlo.txt")
        with open(mpath, "w") as f:
            f.write(mult_hlo)
    # The rust MultiplierKind::DncOpt variant is numerically identical to
    # dnc (the optimization is structural, not arithmetic): alias it.
    for prefix in ("mlp", "mult"):
        src = os.path.join(out, f"{prefix}_dnc.hlo.txt")
        dst = os.path.join(out, f"{prefix}_dnc-opt.hlo.txt")
        with open(src) as f:
            content = f.read()
        with open(dst, "w") as f:
            f.write(content)

    variants = [v for v in VARIANTS] + ["dnc-opt"]
    with open(os.path.join(out, "manifest.txt"), "w") as f:
        f.write(f"dims {','.join(str(d) for d in qmodel.dims)}\n")
        f.write(f"batch {BATCH}\n")
        f.write(f"variants {','.join(variants)}\n")
        f.write(f"train_accuracy {test_acc}\n")
        f.write(f"test_samples {len(test_y)}\n")
    print(f"[aot] wrote manifest; done -> {out}")


if __name__ == "__main__":
    main()
