"""L2 — the quantized MLP in JAX, calling the L1 Pallas kernels.

The model is the paper's motivating workload: a small 4-bit classifier
(64 -> 32 -> 10 over 8x8 digit images) whose every MAC goes through the
LUNA LUT multiplier. Training happens here in float32 (build time only);
the quantized forward pass is what gets AOT-lowered to HLO text and
served by the Rust coordinator.

Bit-compatibility contract with ``rust/src/nn``: identical quantizers
(zero-points 0/8), identical accumulator arithmetic
(``sum lut(w,x) - 8 * sum x``), identical dequant + bias + ReLU order.
"""

from dataclasses import dataclass
from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .kernels.luna_matmul import VARIANTS, luna_matmul
from .quant import Quantizer

DIMS = (64, 32, 10)
# Activation calibration: inputs are pixels in [0,1]; hidden activations
# are clipped to [0, ACT_MAX_HIDDEN] by the quantizer range (mirrored in
# rust by the layer's x_quant scale).
ACT_MAX_HIDDEN = 4.0


# ---------------------------------------------------------------------------
# float training (build-time only)
# ---------------------------------------------------------------------------


def init_params(seed: int):
    """Float parameters [(w [O,I], b [O])] for the DIMS architecture."""
    key = jax.random.PRNGKey(seed)
    params = []
    for i, o in zip(DIMS[:-1], DIMS[1:]):
        key, wk = jax.random.split(key)
        w = jax.random.normal(wk, (o, i), jnp.float32) * jnp.sqrt(2.0 / i)
        params.append((w, jnp.zeros((o,), jnp.float32)))
    return params


def float_forward(params, x):
    h = x
    for li, (w, b) in enumerate(params):
        h = h @ w.T + b
        if li + 1 < len(params):
            h = jax.nn.relu(h)
    return h


def _loss(params, x, y):
    logits = float_forward(params, x)
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=1))


@jax.jit
def _sgd_step(params, x, y, lr):
    loss, grads = jax.value_and_grad(_loss)(params, x, y)
    new = [(w - lr * gw, b - lr * gb) for (w, b), (gw, gb) in zip(params, grads)]
    return new, loss


def train_float(x, y, seed=0, steps=300, batch=64, lr=0.5):
    """Short SGD run; returns (params, final train accuracy)."""
    params = init_params(seed)
    n = len(y)
    rng = np.random.default_rng(seed)
    x = jnp.asarray(x)
    y = jnp.asarray(y)
    for step in range(steps):
        idx = rng.integers(0, n, size=batch)
        params, _ = _sgd_step(params, x[idx], y[idx], lr * (0.97 ** (step // 50)))
    preds = jnp.argmax(float_forward(params, x), axis=1)
    acc = float(jnp.mean((preds == y).astype(jnp.float32)))
    return params, acc


# ---------------------------------------------------------------------------
# quantization + quantized forward (the artifact)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class QuantLayer:
    wq: np.ndarray  # [O, I] int32 codes 0..15
    bias: np.ndarray  # [O] float32
    w_quant: Quantizer
    x_quant: Quantizer
    relu: bool


@dataclass(frozen=True)
class QuantModel:
    layers: Tuple[QuantLayer, ...]

    @property
    def dims(self) -> List[int]:
        return [self.layers[0].wq.shape[1]] + [l.wq.shape[0] for l in self.layers]


def quantize_model(params) -> QuantModel:
    """Quantize float params the same way rust's QuantLinear::from_float does."""
    layers = []
    n = len(params)
    for li, (w, b) in enumerate(params):
        w = np.asarray(w)
        w_quant = Quantizer.for_weights(float(np.max(np.abs(w))))
        x_max = 1.0 if li == 0 else ACT_MAX_HIDDEN
        x_quant = Quantizer.for_activations(x_max)
        layers.append(
            QuantLayer(
                wq=w_quant.quantize_np(w),
                bias=np.asarray(b, np.float32),
                w_quant=w_quant,
                x_quant=x_quant,
                relu=li + 1 < n,
            )
        )
    return QuantModel(tuple(layers))


def quant_forward(model: QuantModel, x, variant: str = "ideal"):
    """Quantized forward pass; every MAC through the Pallas LUT kernel.

    ``x``: [B, 64] float32 in [0, 1]. Returns [B, 10] float32 logits.
    """
    assert variant in VARIANTS, variant
    h = x
    for layer in model.layers:
        xq = layer.x_quant.quantize_jnp(h)
        wq = jnp.asarray(layer.wq, jnp.int32)
        acc = luna_matmul(xq, wq, variant=variant)
        h = acc.astype(jnp.float32) * (layer.w_quant.scale * layer.x_quant.scale)
        h = h + jnp.asarray(layer.bias)
        if layer.relu:
            h = jax.nn.relu(h)
    return h


def quant_accuracy(model: QuantModel, x, y, variant: str = "ideal") -> float:
    logits = quant_forward(model, jnp.asarray(x), variant)
    preds = jnp.argmax(logits, axis=1)
    return float(jnp.mean((preds == np.asarray(y)).astype(jnp.float32)))


# ---------------------------------------------------------------------------
# artifact export (weights.txt shared with rust)
# ---------------------------------------------------------------------------


def weights_text(model: QuantModel) -> str:
    """Render the `luna-mlp-v1` kv format rust's QuantMlp::from_text reads."""
    lines = ["format luna-mlp-v1", f"layers {len(model.layers)}"]
    for i, l in enumerate(model.layers):
        o, k = l.wq.shape
        lines.append(f"layer{i}.in {k}")
        lines.append(f"layer{i}.out {o}")
        lines.append(f"layer{i}.relu {1 if l.relu else 0}")
        lines.append(f"layer{i}.w_scale {l.w_quant.scale!r}")
        lines.append(f"layer{i}.w_zp {l.w_quant.zero_point}")
        lines.append(f"layer{i}.x_scale {l.x_quant.scale!r}")
        lines.append(f"layer{i}.x_zp {l.x_quant.zero_point}")
        lines.append("layer%d.bias %s" % (i, " ".join(repr(float(b)) for b in l.bias)))
        lines.append("layer%d.wq %s" % (i, " ".join(str(int(c)) for c in l.wq.reshape(-1))))
    return "\n".join(lines) + "\n"
