"""Pure-jnp (and pure-python) oracle for the LUNA kernels.

This is the CORE correctness signal: every Pallas kernel is asserted
allclose/equal against these reference implementations (pytest +
hypothesis sweeps in ``python/tests/test_kernels.py``).
"""

import jax.numpy as jnp
import numpy as np


def ref_product(w, y, variant="ideal"):
    """Reference per-scalar product using plain integer arithmetic."""
    w = jnp.asarray(w, jnp.int32)
    y = jnp.asarray(y, jnp.int32)
    y_hi = (y >> 2) & 3
    y_lo = y & 3
    z_msb = w * y_hi
    if variant in ("ideal", "dnc"):
        return w * y  # the D&C identity: (z_msb << 2) + w*y_lo == w*y
    if variant == "approx":
        return z_msb << 2
    if variant == "approx2":
        return (z_msb << 2) + w
    raise ValueError(f"unknown variant {variant!r}")


def ref_matmul(xq, wq, variant="ideal"):
    """Reference quantized matmul with weight zero-point 8.

    [B, K] x [O, K] -> [B, O]:  sum_k f(w, x) - 8 * sum_k x
    """
    xq = jnp.asarray(xq, jnp.int32)
    wq = jnp.asarray(wq, jnp.int32)
    prod = ref_product(wq[None, :, :], xq[:, None, :], variant)
    acc = jnp.sum(prod, axis=-1, dtype=jnp.int32)
    x_sum = jnp.sum(xq, axis=-1, dtype=jnp.int32)
    return acc - 8 * x_sum[:, None]


def ref_product_py(w: int, y: int, variant: str = "ideal") -> int:
    """Scalar python-int version (ground truth for both jnp and rust)."""
    assert 0 <= w < 16 and 0 <= y < 16
    y_hi, y_lo = (y >> 2) & 3, y & 3
    z_msb = w * y_hi
    if variant in ("ideal", "dnc"):
        return w * y
    if variant == "approx":
        return z_msb << 2
    if variant == "approx2":
        return (z_msb << 2) + w
    raise ValueError(variant)


def exhaustive_product_table(variant: str = "ideal") -> np.ndarray:
    """16x16 table of variant products — mirrors rust's error-map inputs."""
    return np.array(
        [[ref_product_py(w, y, variant) for y in range(16)] for w in range(16)],
        dtype=np.int32,
    )
