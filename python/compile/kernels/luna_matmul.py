"""L1 — Pallas kernels for LUNA-CiM LUT-based quantized matmul.

The paper's compute hot-spot is the 4b x 4b multiply performed by LUT
lookup inside the SRAM array. On TPU-ish hardware the analogous structure
is a VMEM-resident *multiples table* + vectorized select (DESIGN.md
SSHardware-Adaptation): for a weight code ``w`` the four LUT rows are
``{0, w, w<<1, (w<<1)+w}`` — derived exactly like the paper's optimized
shared-row LUT (Fig 3: the x2 row is a wired shift, the x3 row a shift-
add) — and the input's 2-bit chunks select among them. No general-purpose
multiplier is used anywhere in the quantized path.

Variants (matching ``rust/src/multiplier``):

* ``ideal``   — exact product (both 2-bit chunks looked up and combined);
* ``dnc``     — the D&C decomposition, bit-identical to ``ideal``;
* ``approx``  — ApproxD&C:  Z_LSB := 0        (Fig 9);
* ``approx2`` — ApproxD&C2: Z_LSB := W        (Fig 10).

All kernels run with ``interpret=True`` — the CPU PJRT plugin cannot
execute Mosaic custom-calls; numerics are validated against ``ref.py``.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

VARIANTS = ("ideal", "dnc", "approx", "approx2")


def lut4_select(w, sel):
    """Select among the derived LUT rows {0, w, 2w, 3w} by a 2-bit code.

    This is the software image of the paper's 4:1 mux over shared rows:
    ``2w`` is a wired shift of the stored ``w`` row and ``3w`` a single
    shift-add; only selects, shifts and adds appear (no multiply).
    """
    w2 = w << 1
    w3 = w2 + w
    return jnp.where(sel == 0, 0, jnp.where(sel == 1, w, jnp.where(sel == 2, w2, w3)))


def variant_product(w, y, variant):
    """Per-scalar 4b x 4b product under a LUNA variant (integer arrays)."""
    y_hi = (y >> 2) & 3
    y_lo = y & 3
    z_msb = lut4_select(w, y_hi)
    if variant in ("ideal", "dnc"):
        return (z_msb << 2) + lut4_select(w, y_lo)
    if variant == "approx":
        return z_msb << 2
    if variant == "approx2":
        return (z_msb << 2) + w
    raise ValueError(f"unknown variant {variant!r}")


def _matmul_kernel(x_ref, w_ref, o_ref, *, variant):
    """Pallas kernel: one (B_tile, O_tile) output block, K resident.

    ``x_ref``: [B, K] int32 activation codes (0..15)
    ``w_ref``: [O, K] int32 weight codes (0..15, zero-point 8)
    ``o_ref``: [B, O] int32 accumulators  sum_k f(w[o,k], x[b,k]) - 8*sum_k x[b,k]
    """
    x = x_ref[...]
    w = w_ref[...]
    # [B, 1, K] x [1, O, K] -> [B, O, K] products via LUT select.
    prod = variant_product(w[None, :, :], x[:, None, :], variant)
    acc = jnp.sum(prod, axis=-1, dtype=jnp.int32)
    # Weight zero-point correction (exact integer arithmetic outside the
    # LUT, mirroring rust's QuantLinear::accumulate).
    x_sum = jnp.sum(x, axis=-1, dtype=jnp.int32)
    o_ref[...] = acc - 8 * x_sum[:, None]


@functools.partial(jax.jit, static_argnames=("variant",))
def luna_matmul(xq, wq, variant="ideal"):
    """Quantized matmul through the LUNA LUT kernel.

    Args:
      xq: [B, K] int32 activation codes in 0..15 (zero-point 0).
      wq: [O, K] int32 weight codes in 0..15 (zero-point 8).
      variant: one of ``VARIANTS``.

    Returns:
      [B, O] int32 accumulators (already zero-point corrected).
    """
    b, k = xq.shape
    o, k2 = wq.shape
    assert k == k2, f"K mismatch: {k} vs {k2}"
    kernel = functools.partial(_matmul_kernel, variant=variant)
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((b, o), jnp.int32),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(xq.astype(jnp.int32), wq.astype(jnp.int32))


def _mult_kernel(w_ref, y_ref, o_ref, *, variant):
    """Standalone elementwise 4b multiplier (bit-accuracy cross-check)."""
    o_ref[...] = variant_product(w_ref[...], y_ref[...], variant)


@functools.partial(jax.jit, static_argnames=("variant",))
def luna_multiply(wq, yq, variant="ideal"):
    """Elementwise LUNA product of two integer-code arrays (same shape)."""
    assert wq.shape == yq.shape
    kernel = functools.partial(_mult_kernel, variant=variant)
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct(wq.shape, jnp.int32),
        interpret=True,
    )(wq.astype(jnp.int32), yq.astype(jnp.int32))
