"""Synthetic 8x8 digits dataset — glyphs shared with
``rust/src/nn/dataset.rs`` (keep GLYPHS in sync!).

The Python generator is used for *training* (build time only). The test
set the Rust runtime evaluates on is exported to ``artifacts/testset.bin``
by ``aot.py``, so the evaluation bits are identical on both sides even
though the two languages use different RNGs.
"""

import numpy as np

# One string per digit, 64 chars, '#' = ink. MUST match rust's GLYPHS.
GLYPHS = [
    ".####...#..#...#..#...#..#...#..#...#..#...#..#...####..........",
    "..##....###.....##......##......##......##......####............",
    ".####...#..#......#.....##.....#......##......####.............",
    ".####......#....###.......#.......#...#..#....###..............",
    ".#..#...#..#...#..#...####......#.......#.......#...............",
    ".####...#......###........#.......#...#..#....###..............",
    "..###...#......####....#..#...#..#...#..#....###...............",
    ".####......#.....#......#......#.......#.......#...............",
    ".####...#..#....##.....#..#...#..#...#..#....####..............",
    ".####...#..#...#..#....####.......#......#....##................",
]


def glyph_pixels(g: str) -> np.ndarray:
    px = np.array([1.0 if c == "#" else 0.0 for c in g], dtype=np.float32)
    return np.resize(px, 64)


def generate(per_digit: int, seed: int):
    """Generate (pixels [N, 64] float32 in [0,1], labels [N] int) samples.

    Same perturbation model as the Rust generator: +-1 pixel shift, 5%
    ink dropout, uniform +-0.12 noise. (The RNG streams differ — only the
    *distribution* must match; the shared test set is exported binary.)
    """
    rng = np.random.default_rng(seed)
    glyphs = [glyph_pixels(g).reshape(8, 8) for g in GLYPHS]
    xs, ys = [], []
    for _ in range(per_digit):
        for label, glyph in enumerate(glyphs):
            dx, dy = rng.integers(-1, 2), rng.integers(-1, 2)
            img = np.zeros((8, 8), dtype=np.float32)
            for y in range(8):
                for x in range(8):
                    sx, sy = x - dx, y - dy
                    if 0 <= sx < 8 and 0 <= sy < 8:
                        img[y, x] = glyph[sy, sx]
            drop = (img > 0.5) & (rng.random((8, 8)) < 0.05)
            img[drop] = 0.0
            img = np.clip(img + rng.uniform(-0.12, 0.12, (8, 8)), 0.0, 1.0)
            xs.append(img.reshape(64).astype(np.float32))
            ys.append(label)
    return np.stack(xs), np.array(ys, dtype=np.int64)


def export_testset(pixels: np.ndarray, labels: np.ndarray) -> bytes:
    """Binary format shared with rust `DigitsDataset::from_binary`:
    u32 N, then per sample 64 f32 LE + u32 label."""
    n = len(labels)
    out = bytearray()
    out += np.uint32(n).tobytes()
    for i in range(n):
        out += pixels[i].astype("<f4").tobytes()
        out += np.uint32(labels[i]).tobytes()
    return bytes(out)
