"""Affine 4-bit quantization — mirrors ``rust/src/nn/quant.rs`` exactly.

Activations: zero-point 0, scale = max_abs / 15.
Weights:     zero-point 8, scale = max_abs / 7 (signed values onto 0..15).
"""

from dataclasses import dataclass

import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class Quantizer:
    scale: float
    zero_point: int

    @staticmethod
    def for_activations(max_abs: float) -> "Quantizer":
        return Quantizer(scale=max(max_abs, 1e-6) / 15.0, zero_point=0)

    @staticmethod
    def for_weights(max_abs: float) -> "Quantizer":
        return Quantizer(scale=max(max_abs, 1e-6) / 7.0, zero_point=8)

    def quantize_np(self, x: np.ndarray) -> np.ndarray:
        q = np.round(x / self.scale) + self.zero_point
        return np.clip(q, 0, 15).astype(np.int32)

    def quantize_jnp(self, x):
        q = jnp.round(x / self.scale) + self.zero_point
        return jnp.clip(q, 0, 15).astype(jnp.int32)

    def dequantize(self, q):
        return (q - self.zero_point) * self.scale
