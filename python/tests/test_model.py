"""L2 correctness: quantized model forward pass and artifact formats."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile import data, model
from compile.kernels.luna_matmul import VARIANTS
from compile.quant import Quantizer


def tiny_model(seed=0):
    params = model.init_params(seed)
    return model.quantize_model(params)


class TestQuantizer:
    def test_weight_quantizer_is_symmetric(self):
        q = Quantizer.for_weights(0.7)
        assert q.zero_point == 8
        assert q.quantize_np(np.array([0.0]))[0] == 8
        assert q.quantize_np(np.array([0.7]))[0] == 15
        assert q.quantize_np(np.array([-0.7]))[0] <= 1

    def test_activation_quantizer_range(self):
        q = Quantizer.for_activations(1.0)
        codes = q.quantize_np(np.linspace(-1, 2, 50))
        assert codes.min() == 0 and codes.max() == 15

    @settings(max_examples=30, deadline=None)
    @given(st.floats(0.01, 10.0), st.lists(st.floats(-5, 5), min_size=1, max_size=20))
    def test_roundtrip_error_bounded(self, max_abs, xs):
        q = Quantizer.for_activations(max_abs)
        xs = np.clip(np.array(xs, dtype=np.float32), 0, max_abs)
        back = q.dequantize(q.quantize_np(xs))
        assert np.all(np.abs(back - xs) <= q.scale / 2 + 1e-5)


class TestQuantForward:
    def test_output_shape_and_finiteness(self):
        qm = tiny_model()
        x = jnp.zeros((4, 64), jnp.float32)
        for variant in VARIANTS:
            out = model.quant_forward(qm, x, variant)
            assert out.shape == (4, 10)
            assert np.all(np.isfinite(np.asarray(out)))

    def test_dnc_equals_ideal_bitwise(self):
        qm = tiny_model()
        x, _ = data.generate(2, 99)
        a = np.asarray(model.quant_forward(qm, jnp.asarray(x), "ideal"))
        b = np.asarray(model.quant_forward(qm, jnp.asarray(x), "dnc"))
        np.testing.assert_array_equal(a, b)

    def test_approx_variants_differ_from_ideal(self):
        qm = tiny_model()
        x, _ = data.generate(2, 98)
        a = np.asarray(model.quant_forward(qm, jnp.asarray(x), "ideal"))
        for variant in ("approx", "approx2"):
            b = np.asarray(model.quant_forward(qm, jnp.asarray(x), variant))
            assert not np.array_equal(a, b), variant

    def test_batch_rows_are_independent(self):
        qm = tiny_model()
        x, _ = data.generate(1, 5)
        single = np.asarray(model.quant_forward(qm, jnp.asarray(x[:1]), "ideal"))
        batched = np.asarray(model.quant_forward(qm, jnp.asarray(x[:8]), "ideal"))
        np.testing.assert_allclose(batched[0], single[0], rtol=1e-6)

    def test_training_improves_over_chance(self):
        x, y = data.generate(30, 1234)
        params, acc = model.train_float(x, y, seed=0, steps=150)
        assert acc > 0.5, f"float training failed to learn (acc {acc})"
        qm = model.quantize_model(params)
        qacc = model.quant_accuracy(qm, x, y, "ideal")
        assert qacc > 0.4, f"quantized accuracy collapsed (acc {qacc})"


class TestWeightsText:
    def test_format_contains_everything_rust_needs(self):
        qm = tiny_model()
        text = model.weights_text(qm)
        assert text.startswith("format luna-mlp-v1")
        assert "layers 2" in text
        for i in range(2):
            for key in ("in", "out", "relu", "w_scale", "w_zp", "x_scale", "x_zp", "bias", "wq"):
                assert f"layer{i}.{key} " in text, key

    def test_codes_are_4bit(self):
        qm = tiny_model()
        for line in model.weights_text(qm).splitlines():
            if ".wq " in line:
                codes = [int(t) for t in line.split()[1:]]
                assert all(0 <= c <= 15 for c in codes)

    def test_code_count_matches_dims(self):
        qm = tiny_model()
        text = model.weights_text(qm)
        lines = {l.split()[0]: l for l in text.splitlines()}
        n0 = len(lines["layer0.wq"].split()) - 1
        assert n0 == 64 * 32


class TestData:
    def test_generation_deterministic(self):
        a, la = data.generate(3, 7)
        b, lb = data.generate(3, 7)
        np.testing.assert_array_equal(a, b)
        np.testing.assert_array_equal(la, lb)
        assert a.shape == (30, 64)

    def test_pixels_in_unit_range(self):
        x, _ = data.generate(5, 3)
        assert x.min() >= 0.0 and x.max() <= 1.0

    def test_export_binary_layout(self):
        x, y = data.generate(1, 2)
        blob = data.export_testset(x, y)
        assert len(blob) == 4 + len(y) * (64 * 4 + 4)
        n = np.frombuffer(blob[:4], dtype="<u4")[0]
        assert n == len(y)
        # first sample pixels round-trip
        px = np.frombuffer(blob[4 : 4 + 256], dtype="<f4")
        np.testing.assert_array_equal(px, x[0])

    def test_glyphs_match_rust_source(self):
        """Guards the cross-language GLYPHS contract (nn/dataset.rs)."""
        import pathlib
        import re

        rust_src = (
            pathlib.Path(__file__).resolve().parents[2] / "rust" / "src" / "nn" / "dataset.rs"
        ).read_text()
        rust_glyphs = re.findall(r'"([.#]{20,})"', rust_src)
        assert rust_glyphs == data.GLYPHS
