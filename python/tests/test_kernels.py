"""L1 correctness: Pallas kernels vs the pure-jnp/python oracle.

Hypothesis sweeps shapes and operand values; exhaustive checks cover the
full 16x16 4-bit input space for every variant.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels.luna_matmul import (
    VARIANTS,
    luna_matmul,
    luna_multiply,
    lut4_select,
    variant_product,
)
from compile.kernels.ref import (
    exhaustive_product_table,
    ref_matmul,
    ref_product,
    ref_product_py,
)


def grids():
    w, y = np.meshgrid(np.arange(16), np.arange(16), indexing="ij")
    return jnp.asarray(w, jnp.int32), jnp.asarray(y, jnp.int32)


class TestLut4Select:
    def test_matches_shift_add_multiples(self):
        w = jnp.arange(16, dtype=jnp.int32)
        for sel in range(4):
            got = lut4_select(w, jnp.full_like(w, sel))
            np.testing.assert_array_equal(np.asarray(got), np.arange(16) * sel)


class TestVariantProduct:
    @pytest.mark.parametrize("variant", VARIANTS)
    def test_exhaustive_against_python_oracle(self, variant):
        w, y = grids()
        got = np.asarray(variant_product(w, y, variant))
        want = exhaustive_product_table(variant)
        np.testing.assert_array_equal(got, want, err_msg=variant)

    def test_dnc_identity_is_exact(self):
        w, y = grids()
        np.testing.assert_array_equal(
            np.asarray(variant_product(w, y, "dnc")), np.asarray(w) * np.asarray(y)
        )

    def test_approx_error_range_matches_fig8(self):
        # error = z_lsb in [0, 45]
        w, y = grids()
        err = np.asarray(w) * np.asarray(y) - np.asarray(variant_product(w, y, "approx"))
        assert err.min() == 0 and err.max() == 45

    def test_approx2_error_range_matches_fig12(self):
        w, y = grids()
        err = np.asarray(w) * np.asarray(y) - np.asarray(variant_product(w, y, "approx2"))
        assert err.min() == -15 and err.max() == 30


class TestLunaMultiplyKernel:
    @pytest.mark.parametrize("variant", VARIANTS)
    def test_exhaustive_grid(self, variant):
        w, y = grids()
        got = np.asarray(luna_multiply(w, y, variant=variant))
        np.testing.assert_array_equal(got, exhaustive_product_table(variant))

    @settings(max_examples=25, deadline=None)
    @given(
        st.integers(1, 5),
        st.integers(1, 17),
        st.sampled_from(VARIANTS),
        st.integers(0, 2**31 - 1),
    )
    def test_random_shapes(self, rows, cols, variant, seed):
        rng = np.random.default_rng(seed)
        w = rng.integers(0, 16, size=(rows, cols))
        y = rng.integers(0, 16, size=(rows, cols))
        got = np.asarray(luna_multiply(jnp.asarray(w), jnp.asarray(y), variant=variant))
        want = np.asarray(ref_product(w, y, variant))
        np.testing.assert_array_equal(got, want)


class TestLunaMatmulKernel:
    @settings(max_examples=30, deadline=None)
    @given(
        st.integers(1, 9),  # B
        st.integers(1, 33),  # K
        st.integers(1, 17),  # O
        st.sampled_from(VARIANTS),
        st.integers(0, 2**31 - 1),
    )
    def test_matches_reference_matmul(self, b, k, o, variant, seed):
        rng = np.random.default_rng(seed)
        xq = rng.integers(0, 16, size=(b, k))
        wq = rng.integers(0, 16, size=(o, k))
        got = np.asarray(luna_matmul(jnp.asarray(xq), jnp.asarray(wq), variant=variant))
        want = np.asarray(ref_matmul(xq, wq, variant))
        np.testing.assert_array_equal(got, want, err_msg=f"{variant} b={b} k={k} o={o}")

    def test_ideal_equals_integer_matmul_with_zp(self):
        rng = np.random.default_rng(7)
        xq = rng.integers(0, 16, size=(4, 12))
        wq = rng.integers(0, 16, size=(6, 12))
        got = np.asarray(luna_matmul(jnp.asarray(xq), jnp.asarray(wq), variant="ideal"))
        want = np.einsum("ok,bk->bo", wq, xq) - 8 * xq.sum(axis=1)[:, None]
        np.testing.assert_array_equal(got, want)

    def test_zero_inputs_give_zero(self):
        z = jnp.zeros((3, 8), jnp.int32)
        w = jnp.ones((4, 8), jnp.int32) * 5
        out = np.asarray(luna_matmul(z, w, variant="ideal"))
        np.testing.assert_array_equal(out, np.zeros((3, 4)))

    @pytest.mark.parametrize("variant", ["approx", "approx2"])
    def test_variant_error_bounded_per_element(self, variant):
        # |acc_variant - acc_ideal| <= K * bound (45 for approx, 30 for approx2)
        rng = np.random.default_rng(11)
        k = 16
        xq = rng.integers(0, 16, size=(5, k))
        wq = rng.integers(0, 16, size=(7, k))
        a = np.asarray(luna_matmul(jnp.asarray(xq), jnp.asarray(wq), variant="ideal"))
        b = np.asarray(luna_matmul(jnp.asarray(xq), jnp.asarray(wq), variant=variant))
        bound = 45 if variant == "approx" else 30
        assert np.max(np.abs(a - b)) <= k * bound


class TestScalarOracleConsistency:
    def test_python_and_jnp_oracles_agree(self):
        for variant in VARIANTS:
            for w in range(16):
                for y in range(16):
                    assert int(ref_product(w, y, variant)) == ref_product_py(w, y, variant)
