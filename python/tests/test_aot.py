"""AOT lowering smoke tests: HLO text is produced and well-formed."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import aot, model


def test_to_hlo_text_roundtrips_simple_fn():
    def fn(x):
        return (x * 2.0 + 1.0,)

    lowered = jax.jit(fn).lower(jax.ShapeDtypeStruct((2, 2), jnp.float32))
    text = aot.to_hlo_text(lowered)
    assert "ENTRY" in text
    assert "f32[2,2]" in text


def test_lower_mult_variant_produces_hlo():
    text = aot.lower_mult_variant("approx")
    assert "ENTRY" in text
    assert "f32[16,16]" in text


def test_lower_mlp_variant_produces_hlo():
    params = model.init_params(0)
    qm = model.quantize_model(params)
    text = aot.lower_mlp_variant(qm, "ideal")
    assert "ENTRY" in text
    # batch x input and batch x output shapes appear
    assert f"f32[{aot.BATCH},{model.DIMS[0]}]" in text
    assert f"f32[{aot.BATCH},{model.DIMS[-1]}]" in text


def test_lowered_mlp_is_pure_hlo_no_custom_calls():
    """interpret=True must lower pallas to plain HLO ops the CPU PJRT
    client can execute — a Mosaic custom-call would break the Rust side."""
    params = model.init_params(1)
    qm = model.quantize_model(params)
    for variant in ("ideal", "approx"):
        text = aot.lower_mlp_variant(qm, variant)
        assert "custom-call" not in text, f"{variant} lowered to a custom call"


def test_quant_forward_matches_float_loosely():
    """Quantization error stays small enough that logits correlate."""
    x, y = __import__("compile.data", fromlist=["generate"]).generate(5, 42)
    params, _ = model.train_float(x, y, steps=60)
    qm = model.quantize_model(params)
    f = np.asarray(model.float_forward(params, jnp.asarray(x[:8])))
    q = np.asarray(model.quant_forward(qm, jnp.asarray(x[:8]), "ideal"))
    # predictions mostly agree
    agree = np.mean(np.argmax(f, 1) == np.argmax(q, 1))
    assert agree >= 0.5, f"quantized/float prediction agreement {agree}"
